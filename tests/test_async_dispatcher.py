"""Async serving runtime (repro.serve.async_dispatcher): out-of-order future
resolution, backpressure under a saturated worker pool, priority preemption
of the stride scheduler, SLO deadline-miss accounting, the EWMA service-time
cost model, and the determinism guarantee (async results bit-identical to
the sync dispatcher on the same submissions)."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comanager.worker import WorkerConfig
from repro.core.quclassi import QuClassiConfig
from repro.kernels import ops as kops
from repro.serve import (
    Backpressure,
    CoalescedBatch,
    Gateway,
    GatewayRuntime,
    PendingCircuit,
    ServiceModel,
    batch_cost_units,
)


def wait_until(pred, timeout=10.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.005)
    return False


@pytest.fixture(scope="module")
def specs():
    cfg5 = QuClassiConfig(qc=5, n_layers=1)
    cfg7 = QuClassiConfig(qc=7, n_layers=1)
    return cfg5, cfg7


def rows_for(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.uniform(0, np.pi, (n, cfg.n_theta)), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (n, cfg.n_angles)), jnp.float32)
    return theta, data


def gated_kernel(block_widths, gate: threading.Event):
    """A kernel that stalls batches of the given qubit widths on ``gate``."""

    def kernel(spec, theta, data):
        if spec.n_qubits in block_widths:
            assert gate.wait(timeout=30.0), "test gate never released"
        return kops.vqc_fidelity(spec, theta, data)

    return kernel


# ------------------------------------------------- out-of-order resolution
def test_futures_resolve_out_of_order(specs):
    """A stalled mega-batch on one worker must not block another tenant's
    batch executing on a different worker slot: the later submission's
    futures resolve first."""
    cfg5, cfg7 = specs
    gate = threading.Event()
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5), WorkerConfig("w2", 10)],
        target=8,
        lanes=8,
        deadline=0.05,
        mode="async",
        kernel=gated_kernel({5}, gate),
    )
    try:
        t5, d5 = rows_for(cfg5, 8)
        t7, d7 = rows_for(cfg7, 8)
        now = rt.dispatcher.clock
        slow = [
            rt.gateway.submit("tenant", cfg5.spec, (t5[i], d5[i]), now())
            for i in range(8)
        ]
        fast = [
            rt.gateway.submit("tenant", cfg7.spec, (t7[i], d7[i]), now())
            for i in range(8)
        ]
        rt.dispatcher.kick()
        for f in fast:
            f.result(timeout=30.0)
        assert not any(f.done for f in slow), "stalled batch resolved early"
        gate.set()
        for f in slow:
            f.result(timeout=30.0)
        ref = kops.vqc_fidelity(cfg5.spec, t5, d5)
        got = jnp.stack([f.value for f in slow])
        assert np.array_equal(np.asarray(ref), np.asarray(got))
    finally:
        gate.set()
        rt.close()


# --------------------------------------------- backpressure under saturation
def test_backpressure_when_worker_pool_saturated(specs):
    """With the single worker slot stalled and the tenant at its in-flight
    cap, the admission queue fills and submit raises Backpressure; releasing
    the pool drains everything."""
    cfg5, _ = specs
    gate = threading.Event()
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)],
        target=4,
        lanes=4,
        deadline=10.0,
        mode="async",
        kernel=gated_kernel({5}, gate),
    )
    try:
        rt.gateway.register_client("t", max_pending=4, max_in_flight=4)
        theta, data = rows_for(cfg5, 9)
        now = rt.dispatcher.clock
        futs = [
            rt.gateway.submit("t", cfg5.spec, (theta[i], data[i]), now())
            for i in range(4)
        ]
        rt.dispatcher.kick()
        assert wait_until(lambda: rt.dispatcher.in_flight_batches == 1)
        futs += [
            rt.gateway.submit("t", cfg5.spec, (theta[i], data[i]), now())
            for i in range(4, 8)
        ]
        with pytest.raises(Backpressure):
            rt.gateway.submit("t", cfg5.spec, (theta[8], data[8]), now())
        assert rt.telemetry.tenants["t"].rejected == 1
        gate.set()
        rt.dispatcher.drain()
        assert all(f.done for f in futs)
    finally:
        gate.set()
        rt.close()


# --------------------------------------------------- priority tier preemption
def test_priority_tier_preempts_stride_scheduling():
    """A tier-0 tenant joining late is served strictly before tier-1 backlog
    regardless of accumulated virtual passes."""
    g = Gateway(target=128, lanes=128, deadline=100.0)
    g.register_client("batch", weight=10.0, priority=1)
    for i in range(20):
        g.submit("batch", "k", i, now=0.0)
    g.pump(now=0.0)  # batch's vpass advances well past 0
    g.register_client("interactive", priority=0)
    for i in range(5):
        g.submit("interactive", "k", 100 + i, now=1.0)
        g.submit("batch", "k", 200 + i, now=1.0)
    g.pump(now=1.0)
    tail = [m.client_id for m in g.coalescer._buffers["k"]][20:]
    assert tail[:5] == ["interactive"] * 5
    assert tail[5:] == ["batch"] * 5


def test_priority_preemption_through_async_runtime(specs):
    """End to end: with one stalled slot, a tier-0 tenant's circuits jump the
    tier-1 backlog when the slot frees."""
    cfg5, _ = specs
    gate = threading.Event()
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)],
        target=4,
        lanes=4,
        deadline=10.0,
        mode="async",
        kernel=gated_kernel({5}, gate),
    )
    try:
        rt.gateway.register_client("bulk", priority=1, max_in_flight=4)
        rt.gateway.register_client("vip", priority=0)
        theta, data = rows_for(cfg5, 12)
        now = rt.dispatcher.clock
        bulk = [
            rt.gateway.submit("bulk", cfg5.spec, (theta[i], data[i]), now())
            for i in range(8)
        ]
        rt.dispatcher.kick()
        assert wait_until(lambda: rt.dispatcher.in_flight_batches == 1)
        vip = [
            rt.gateway.submit("vip", cfg5.spec, (theta[i], data[i]), now())
            for i in range(8, 12)
        ]
        rt.dispatcher.kick()
        gate.set()
        rt.dispatcher.drain()
        assert all(f.done for f in bulk) and all(f.done for f in vip)
        # batch 1 = bulk's first four (already in flight before vip joined);
        # batch 2 must be all-vip: the tier-0 queue preempted bulk's backlog.
        second = rt.dispatcher.batch_log[1]
        assert second[2] == ("vip",)
    finally:
        gate.set()
        rt.close()


# ------------------------------------------------------- SLO deadline misses
def test_slo_flush_deadline_shortens_coalescer_wait():
    """A tenant SLO shrinks the flush deadline to half the SLO budget."""
    g = Gateway(target=128, lanes=128, deadline=10.0)
    g.register_client("fast", slo_ms=100.0)
    g.register_client("easy")
    g.submit("easy", "k", 0, now=0.0)
    g.pump(now=0.0)
    assert g.next_deadline() == pytest.approx(10.0)  # default deadline
    g.submit("fast", "k", 1, now=0.0)
    g.pump(now=0.0)
    # min over members: the SLO tenant pulls the shared buffer forward
    assert g.next_deadline() == pytest.approx(0.05)
    assert g.pump(now=0.04) == []
    (batch,) = g.pump(now=0.05)
    assert batch.by_deadline and batch.n == 2


def test_slo_miss_accounting(specs):
    """Completions past the SLO are counted per tenant; attainment reported."""
    cfg5, _ = specs

    def slow_kernel(spec, theta, data):
        time.sleep(0.05)
        return kops.vqc_fidelity(spec, theta, data)

    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5), WorkerConfig("w2", 5)],
        target=4,
        lanes=4,
        deadline=0.01,
        mode="async",
        kernel=slow_kernel,
    )
    try:
        theta, data = rows_for(cfg5, 8)
        ex_tight = rt.executor(cfg5.spec, "tight", slo_ms=1.0)
        ex_loose = rt.executor(cfg5.spec, "loose", slo_ms=60_000.0)
        ex_tight(theta[:4], data[:4])
        ex_loose(theta[4:], data[4:])
        tight = rt.telemetry.tenants["tight"]
        loose = rt.telemetry.tenants["loose"]
        assert tight.slo_misses == tight.completed == 4
        assert tight.slo_attainment == 0.0
        assert loose.slo_misses == 0 and loose.slo_attainment == 1.0
        summary = rt.telemetry.summary()
        assert summary["slo_misses"] == 4
        assert 0.0 < summary["slo_attainment"] < 1.0
    finally:
        rt.close()


# ----------------------------------------------------- EWMA service estimates
def test_service_model_ewma_converges():
    m = ServiceModel(alpha=0.5, default_s=1.0)
    assert m.estimate("k", 100.0) == 1.0  # no observations: default
    m.update("k", 100.0, 2.0)  # 0.02 s/unit
    assert m.estimate("k", 100.0) == pytest.approx(2.0)
    m.update("k", 100.0, 4.0)  # ewma: 0.5*0.04 + 0.5*0.02
    assert m.estimate("k", 100.0) == pytest.approx(3.0)
    # unseen keys fall back to the global ewma, not the flat default
    assert m.estimate("other", 100.0) == pytest.approx(3.0)


def test_batch_cost_units_scale_with_lanes_and_suffix(specs):
    cfg5, _ = specs
    spec = cfg5.spec

    def row_batch(n):
        members = [
            PendingCircuit(key=spec, client_id="c", seq=i, arrival=0.0, payload=None)
            for i in range(n)
        ]
        return CoalescedBatch(key=spec, members=members, created=0.0)

    small, large = batch_cost_units(row_batch(8)), batch_cost_units(row_batch(200))
    # 8 rows pad to one 128-lane tile, 200 rows to two
    assert large == pytest.approx(2 * small)
    assert small == len(spec.ops) * 128


def test_ewma_feeds_worker_cru(specs):
    """Predicted service seconds are charged to the assigned worker's CRU
    while a batch is outstanding, steering Algorithm 2 elsewhere."""
    cfg5, _ = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5), WorkerConfig("w2", 5)],
        target=4,
        lanes=4,
        deadline=10.0,
    )
    try:
        d = rt.dispatcher
        d._charge("w1", 2.5)
        assert d.manager.workers["w1"].cru == pytest.approx(2.5)
        assert d.manager.workers["w2"].cru == 0.0
        theta, data = rows_for(cfg5, 4)
        now = d.clock
        for i in range(4):
            rt.gateway.submit("c", cfg5.spec, (theta[i], data[i]), now())
        d.drain()
        # the charged worker lost the CRU tiebreak: the batch ran on w2
        assert d.batch_log[0][0] == "w2"
        d._charge("w1", -2.5)
        assert d.manager.workers["w1"].cru == pytest.approx(0.0)
        # execution updated the EWMA: estimates are no longer the default
        est = rt.telemetry.service.estimate(cfg5.spec, 1.0)
        assert 0.0 < est < 1.0
    finally:
        rt.close()


# ------------------------------------------------------ determinism / safety
def test_async_results_bit_identical_to_sync(specs):
    """Acceptance: the async dispatcher returns bit-identical fidelities to
    the sync dispatcher on the same submissions (batch composition never
    changes per-lane math)."""
    cfg5, _ = specs
    theta, data = rows_for(cfg5, 70)
    rt_sync = GatewayRuntime(target=128, deadline=0.1)
    f_sync = rt_sync.executor(cfg5.spec, "c")(theta, data)
    rt_async = GatewayRuntime(
        target=128, deadline=0.1, mode="async", slots_per_worker=2
    )
    try:
        f_async = rt_async.executor(cfg5.spec, "c")(theta, data)
    finally:
        rt_async.close()
    assert np.array_equal(np.asarray(f_sync), np.asarray(f_async))


def test_async_shift_executor_matches_local_gradient(specs):
    """Implicit shift-bank group subtasks ride the async path too, and the
    assembled gradient matches the local executor."""
    from repro.core import quclassi

    cfg5, _ = specs
    import jax

    from repro.data import mnist

    x, y = mnist.make_pair_dataset(3, 9, n_per_class=4, seed=0)
    x, y = jnp.asarray(x[:2]), jnp.asarray(y[:2])
    params = quclassi.init_params(cfg5, jax.random.PRNGKey(0))
    l_ref, g_ref, _ = quclassi.grad_shift(cfg5, params, x, y, implicit=True)
    rt = GatewayRuntime(target=128, deadline=0.2, mode="async")
    try:
        ex = rt.shift_executor(cfg5.spec, "t1")
        l_gw, g_gw, _ = quclassi.grad_shift(
            cfg5, params, x, y, executor=ex, implicit=True
        )
    finally:
        rt.close()
    assert float(l_gw) == pytest.approx(float(l_ref), abs=1e-6)
    np.testing.assert_allclose(
        np.asarray(g_gw["theta"]), np.asarray(g_ref["theta"]), atol=1e-5
    )


def test_concurrent_submitters_do_not_corrupt_state(specs):
    """Satellite: user threads hammering submit while the pump and worker
    pool run — per-thread results stay correct and counts balance."""
    cfg5, _ = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5), WorkerConfig("w2", 10)],
        target=32,
        lanes=32,
        deadline=0.02,
        mode="async",
    )
    results = {}

    def client(tid):
        theta, data = rows_for(cfg5, 40, seed=tid)
        ex = rt.executor(cfg5.spec, f"c{tid}")
        results[tid] = (np.asarray(ex(theta, data)), theta, data)

    try:
        threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120.0)
        assert not any(t.is_alive() for t in threads)
        assert not rt.dispatcher.errors
        for tid, (got, theta, data) in results.items():
            ref = np.asarray(kops.vqc_fidelity(cfg5.spec, theta, data))
            np.testing.assert_array_equal(got, ref)
        for tid in range(4):
            s = rt.telemetry.tenants[f"c{tid}"]
            assert s.completed == s.submitted == 40
    finally:
        rt.close()


def test_drain_surfaces_pump_loop_errors(specs):
    """A wedged pump loop must fail drain() with its error, not hang it."""
    cfg5, _ = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)], target=4, lanes=4, mode="async"
    )
    try:

        def boom():
            raise ValueError("pump exploded")

        rt.dispatcher._pump_once = boom
        rt.dispatcher.kick()
        assert wait_until(lambda: rt.dispatcher.errors)
        with pytest.raises(ValueError, match="pump exploded"):
            rt.dispatcher.drain()
    finally:
        rt.close()


def test_worker_pool_executor_matches_sequential(specs):
    """The thread-pooled dataplane executor returns bank-order results
    bit-identical to the sequential per-worker executor, for materialized
    rows and implicit shift banks alike."""
    from repro.comanager import dataplane
    from repro.core import shift_rule

    cfg5, _ = specs
    theta, data = rows_for(cfg5, 30)
    assignment = dataplane.round_robin_assignment(30, 3)
    f_seq = dataplane.worker_batched_executor(cfg5.spec, assignment, 3)(theta, data)
    f_pool = dataplane.worker_pool_executor(cfg5.spec, assignment, 3)(theta, data)
    assert np.array_equal(np.asarray(f_seq), np.asarray(f_pool))

    bank = shift_rule.build_shift_bank(theta[0], data[:4])
    groups = dataplane.round_robin_assignment(bank.n_groups, 3)
    g_seq = dataplane.worker_batched_executor(cfg5.spec, groups, 3)(bank)
    g_pool = dataplane.worker_pool_executor(cfg5.spec, groups, 3)(bank)
    assert np.array_equal(np.asarray(g_seq), np.asarray(g_pool))


def test_oversized_batch_spills_to_mesh(specs):
    """A batch wider than every worker no longer fails fast: it routes
    through the whole-mesh sharded executor, completes with correct
    fidelities, and the spill is visible in telemetry."""
    _, cfg7 = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)],
        target=4,
        lanes=4,
        deadline=0.01,
        mode="async",
    )
    try:
        theta, data = rows_for(cfg7, 2)
        futs = [
            rt.gateway.submit(
                "c", cfg7.spec, (theta[i], data[i]), rt.dispatcher.clock()
            )
            for i in range(2)
        ]
        rt.dispatcher.kick()
        got = np.asarray([np.asarray(f.result(timeout=60.0)) for f in futs])
        ref = np.asarray(kops.vqc_fidelity(cfg7.spec, theta, data))
        np.testing.assert_allclose(got, ref, atol=1e-6)
        assert rt.telemetry.mesh_spills >= 1
        assert rt.telemetry.spilled_lanes >= 2
        assert any(wid == "mesh" for wid, _, _ in rt.dispatcher.batch_log)
        assert not rt.dispatcher.errors
    finally:
        rt.close()


def test_oversized_batch_fails_fast_when_spill_disabled(specs):
    """mesh_spill=False restores the strict contract: futures resolve with
    the placement error instead of deadlocking the pump."""
    _, cfg7 = specs
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)],
        target=4,
        lanes=4,
        deadline=0.01,
        mode="async",
        mesh_spill=False,
    )
    try:
        theta, data = rows_for(cfg7, 1)
        fut = rt.gateway.submit(
            "c", cfg7.spec, (theta[0], data[0]), rt.dispatcher.clock()
        )
        rt.dispatcher.kick()
        with pytest.raises(RuntimeError, match="no worker fits"):
            fut.result(timeout=10.0)
    finally:
        rt.close()


def test_sync_dispatcher_spills_oversized_batches(specs):
    """The sync dispatcher spills too: an over-width bank executes on the
    mesh inline with bit-correct results."""
    from repro.core import shift_rule

    _, cfg7 = specs
    rt = GatewayRuntime(workers=[WorkerConfig("w1", 5)], deadline=0.01)
    try:
        theta, data = rows_for(cfg7, 3)
        bank = shift_rule.build_shift_bank(theta[0], data)
        got = rt.shift_executor(cfg7.spec, "c")(bank)
        want = kops.vqc_fidelity_shiftbank(cfg7.spec, bank.theta, bank.data)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
        assert rt.telemetry.mesh_spills >= 1
        assert rt.dispatcher.batch_log[0][0] == "mesh"
    finally:
        rt.close()


def test_vmem_model_flags_deep_row_batches():
    """batch_vmem_bytes: a 17-qubit row batch's statevector tile blows the
    16 MB per-worker model (spill), a 7-qubit one does not."""
    from repro.core import circuits
    from repro.serve import WORKER_VMEM_BYTES, batch_vmem_bytes

    def row_batch(spec, n):
        members = [
            PendingCircuit(key=spec, client_id="c", seq=i, arrival=0.0, payload=None)
            for i in range(n)
        ]
        return CoalescedBatch(key=spec, members=members, created=0.0)

    wide = circuits.build_quclassi_circuit(17, 1)
    assert batch_vmem_bytes(row_batch(wide, 8)) > WORKER_VMEM_BYTES
    narrow = circuits.build_quclassi_circuit(7, 1)
    assert batch_vmem_bytes(row_batch(narrow, 8)) <= WORKER_VMEM_BYTES


# ------------------------------------------------- preemptive SLO eviction
def test_over_slo_batches_preemptively_evicted(specs):
    """With evict_over_slo on, a ready batch whose members' SLO budgets
    fully elapsed behind a stalled worker resolves with DeadlineExceeded
    and is accounted (evicted + slo miss), instead of burning a slot on a
    guaranteed miss."""
    from repro.serve import DeadlineExceeded

    cfg5, _ = specs
    gate = threading.Event()
    rt = GatewayRuntime(
        workers=[WorkerConfig("w1", 5)],
        target=2,
        lanes=2,
        deadline=0.01,
        mode="async",
        evict_over_slo=True,
        kernel=gated_kernel({5}, gate),
    )
    try:
        rt.gateway.register_client("t", slo_ms=150.0)
        theta, data = rows_for(cfg5, 4)
        now = rt.dispatcher.clock
        first = [
            rt.gateway.submit("t", cfg5.spec, (theta[i], data[i]), now())
            for i in range(2)
        ]
        rt.dispatcher.kick()
        assert wait_until(lambda: rt.dispatcher.in_flight_batches == 1)
        # second batch can only wait in the ready queue (slot is stalled);
        # its 150 ms SLO budget fully elapses -> preemptive eviction
        second = [
            rt.gateway.submit("t", cfg5.spec, (theta[i], data[i]), now())
            for i in range(2, 4)
        ]
        rt.dispatcher.kick()
        assert wait_until(
            lambda: rt.telemetry.tenants["t"].evicted == 2, timeout=30.0
        )
        for f in second:
            with pytest.raises(DeadlineExceeded):
                f.result(timeout=10.0)
        gate.set()
        rt.dispatcher.drain()
        stats = rt.telemetry.tenants["t"]
        assert stats.evicted == 2
        assert stats.slo_misses >= 2          # evictions count as misses
        assert stats.completed == 2           # first batch still completed
        assert stats.slo_attainment <= 0.5
        assert rt.telemetry.summary()["evicted"] == 2
        assert all(f.done for f in first)
    finally:
        gate.set()
        rt.close()


def test_eviction_spares_batches_with_best_effort_members():
    """A mixed batch containing a best-effort member is never evicted —
    that member's result is still wanted whenever it arrives."""
    from repro.serve.async_dispatcher import AsyncDispatcher
    from repro.serve.coalescer import CoalescedBatch as CB

    g = Gateway(target=4, lanes=4, deadline=10.0)
    g.register_client("slo", slo_ms=10.0)
    g.register_client("be")
    d = AsyncDispatcher(g, [WorkerConfig("w1", 5)], evict_over_slo=True)
    try:
        g.submit("slo", "k", None, now=0.0)
        g.submit("be", "k", None, now=0.0)
        (batch,) = g.flush(now=0.0)
        assert not d._expired(batch, now=100.0)        # best-effort member
        slo_m = next(m for m in batch.members if m.client_id == "slo")
        slo_only = CB(key="k", members=[slo_m], created=0.0)
        assert d._expired(slo_only, now=100.0)
        assert not d._expired(slo_only, now=0.005)     # within budget
    finally:
        d.close()


# ------------------------------------- mixed-bank SLO-aware deadline flush
def test_mixed_slo_bank_buffer_flushes_at_min_member_budget(specs):
    """Deterministic (virtual-clock) half: a shared ShiftGroupKey buffer
    holding group subtasks of banks with DIFFERENT slo_ms flushes at the
    MIN member budget — the tight tenant pulls the loose tenant's bank
    forward with it."""
    from repro.core import shift_rule
    from repro.serve import ShiftGroupKey

    cfg5, _ = specs
    g = Gateway(target=128, lanes=128, deadline=10.0)
    g.register_client("tight", slo_ms=500.0)
    g.register_client("loose", slo_ms=60_000.0)
    theta, data = rows_for(cfg5, 8)
    bank_a = shift_rule.build_shift_bank(theta[0], data[:4])
    bank_b = shift_rule.build_shift_bank(theta[1], data[4:])
    key = ShiftGroupKey(cfg5.spec, False)
    for grp in range(bank_b.n_groups):
        g.submit("loose", key, (bank_b, grp), now=0.0, lanes=4)
    assert g.pump(now=0.0) == []
    # loose alone: flush at min(deadline, 0.5 * 60 s) = the 10 s deadline
    assert g.next_deadline() == pytest.approx(10.0)
    for grp in range(bank_a.n_groups):
        g.submit("tight", key, (bank_a, grp), now=0.0, lanes=4)
    assert g.pump(now=0.0) == []
    # tight joins the SAME buffer: min member budget = 0.5 * 0.5 s
    assert g.next_deadline() == pytest.approx(0.25)
    assert g.pump(now=0.2) == []
    (batch,) = g.pump(now=0.25)
    assert batch.by_deadline
    assert batch.n == bank_a.n_groups + bank_b.n_groups
    assert {m.client_id for m in batch.members} == {"tight", "loose"}


def test_mixed_slo_banks_stay_bit_exact_after_fusion(specs):
    """Real-execution half: the mixed-SLO shared buffer fuses into
    multi-bank launches through the async runtime and every fidelity is
    bit-identical to the per-bank implicit path."""
    from repro.core import shift_rule
    from repro.serve import ShiftGroupKey

    cfg5, _ = specs
    spec = cfg5.spec
    rt = GatewayRuntime(deadline=0.2, mode="async")
    try:
        rt.gateway.register_client("tight", slo_ms=500.0)
        rt.gateway.register_client("loose", slo_ms=60_000.0)
        theta, data = rows_for(cfg5, 8)
        bank_a = shift_rule.build_shift_bank(theta[0], data[:4])
        bank_b = shift_rule.build_shift_bank(theta[1], data[4:])
        key = ShiftGroupKey(spec, False)
        now = rt.dispatcher.clock
        futs_a = [
            rt.gateway.submit("tight", key, (bank_a, g), now(), lanes=4)
            for g in range(bank_a.n_groups)
        ]
        futs_b = [
            rt.gateway.submit("loose", key, (bank_b, g), now(), lanes=4)
            for g in range(bank_b.n_groups)
        ]
        rt.dispatcher.kick()
        got_a = jnp.concatenate([f.result(timeout=30.0) for f in futs_a])
        got_b = jnp.concatenate([f.result(timeout=30.0) for f in futs_b])
        want_a = kops.vqc_fidelity_shiftbank(spec, bank_a.theta, bank_a.data)
        want_b = kops.vqc_fidelity_shiftbank(spec, bank_b.theta, bank_b.data)
        assert np.array_equal(np.asarray(got_a), np.asarray(want_a))
        assert np.array_equal(np.asarray(got_b), np.asarray(want_b))
        assert rt.telemetry.fused_launches >= 1
        assert rt.telemetry.fused_banks >= 2
    finally:
        rt.close()
