"""Observability layer: streaming histograms, lifecycle tracing, exporters.

Covers the tentpole contracts:
  * ``LogHistogram`` percentiles stay within one bucket width (x ``growth``)
    of the exact order statistic, at O(1) memory;
  * ``TenantStats`` latency accounting survives the list -> histogram swap
    with the same tolerance;
  * ``TraceBuffer`` is a bounded ring; sampling is deterministic in the
    admission sequence number and a zero rate is a structural no-op;
  * a seeded ``SystemSimulation`` exports a bit-identical Chrome trace
    (golden snapshot — regenerate with
    ``PYTHONPATH=src python tests/test_observability.py --update``);
  * real-dispatcher traces are well-formed: monotone stage timestamps, no
    orphan (unclosed) spans, eviction spans closed;
  * ``Telemetry.summary()`` exposes the ``ServiceModel`` EWMA state.
"""

import json
import math
import pathlib

import numpy as np
import pytest

from repro.obs import (
    CircuitTrace,
    LogHistogram,
    ObservabilityConfig,
    TraceBuffer,
    TraceRecorder,
    WorkerTimeline,
    validate_trace,
)

TRACE_SNAPSHOT = pathlib.Path(__file__).parent / "snapshots" / "gateway_trace.json"


# ------------------------------------------------------------- histograms
def test_log_histogram_percentile_within_one_bucket():
    rng = np.random.default_rng(7)
    values = rng.lognormal(mean=-3.0, sigma=2.0, size=5000)
    h = LogHistogram()
    for v in values:
        h.record(float(v))
    xs = np.sort(values)
    for q in (1, 10, 50, 90, 99, 99.9):
        exact = float(xs[max(0, min(len(xs) - 1, math.ceil(q / 100 * len(xs)) - 1))])
        got = h.percentile(q)
        # one log-bucket of relative error in either direction
        assert exact / h.growth <= got <= exact * h.growth, (q, exact, got)


def test_log_histogram_mean_count_minmax_exact():
    h = LogHistogram()
    values = [0.001, 0.5, 2.0, 2.0, 40.0]
    for v in values:
        h.record(v)
    assert h.count == len(values)
    assert h.mean == pytest.approx(sum(values) / len(values))
    assert h.min_seen == min(values)
    assert h.max_seen == max(values)


def test_log_histogram_fixed_memory_and_zero_bucket():
    h = LogHistogram(n_buckets=32)
    for i in range(100_000):
        h.record((i % 1000) * 1e-5)  # includes exact zeros
    assert len(h.counts) == 32  # no growth, ever
    assert h.zeros > 0
    assert h.count == 100_000
    assert 0.0 <= h.percentile(0.1) <= h.v_min + 1e-12


def test_log_histogram_merge_and_validation():
    a, b = LogHistogram(), LogHistogram()
    for v in (0.1, 0.2):
        a.record(v)
    for v in (0.4, 0.8):
        b.record(v)
    a.merge(b)
    assert a.count == 4
    assert a.max_seen == 0.8
    with pytest.raises(ValueError):
        a.merge(LogHistogram(n_buckets=16))
    with pytest.raises(ValueError):
        LogHistogram(growth=1.0)


def test_tenant_stats_percentile_within_one_bucket():
    """Satellite: the TenantStats list -> histogram swap keeps
    latency_percentile within one bucket width of exact."""
    from repro.serve.metrics import Telemetry

    t = Telemetry()
    rng = np.random.default_rng(3)
    lats = rng.lognormal(mean=-1.0, sigma=1.0, size=2000)
    for lat in lats:
        t.on_submit("a", 0.0)
        t.on_complete("a", 0.0, float(lat))
    xs = np.sort(lats)
    s = t.tenants["a"]
    growth = s.latencies.growth
    for q in (50, 99):
        exact = float(xs[math.ceil(q / 100 * len(xs)) - 1])
        got = s.latency_percentile(q)
        assert exact / growth <= got <= exact * growth
    # O(1) memory: the histogram's bucket array never grows with samples
    assert len(s.latencies.counts) == s.latencies.n_buckets


# ------------------------------------------------------------- ring buffer
def test_trace_buffer_bounded_ring():
    buf = TraceBuffer(capacity=8)
    for i in range(20):
        buf.append(CircuitTrace(seq=i, tenant="t", key="k", stages=[("submit", i)]))
    assert len(buf) == 8
    assert buf.appended == 20
    assert buf.dropped == 12
    assert [r.seq for r in buf][0] == 12  # oldest evicted first


# --------------------------------------------------------------- sampling
def test_sampling_deterministic_and_fractional():
    cfg = ObservabilityConfig(sample_rate=0.25)
    r1, r2 = TraceRecorder(cfg), TraceRecorder(cfg)
    picks1 = [r1.sampled(i) for i in range(4000)]
    picks2 = [r2.sampled(i) for i in range(4000)]
    assert picks1 == picks2  # pure function of seq
    frac = sum(picks1) / len(picks1)
    assert 0.2 < frac < 0.3
    assert all(TraceRecorder(ObservabilityConfig()).sampled(i) for i in range(100))


def test_sampling_zero_is_noop():
    r = TraceRecorder(ObservabilityConfig(sample_rate=0.0))
    assert not r.enabled
    r.circuit_submit(0, "t", "k", 0.0, queue_depth=3)
    r.circuit_stage(0, "admit", 0.1)
    r.circuit_end(0, "complete", 0.2)
    r.worker_span("w1", 0.0, 1.0)
    r.coalescer_sample(4, 4)
    r.on_kernel_launch({"mode": "fused"})
    assert r.events == 0
    assert len(r.buffer) == 0
    assert r.open_traces == 0
    assert not r.stage_hists and not r.timelines and not r.kernel_launches


def test_stage_filtering():
    r = TraceRecorder(ObservabilityConfig(stages=("submit", "kernel_start")))
    r.circuit_submit(0, "t", "k", 0.0)
    r.circuit_stage(0, "admit", 0.1)        # filtered out
    r.circuit_stage(0, "kernel_start", 0.2)  # kept
    r.circuit_end(0, "complete", 0.3)        # terminal: always recorded
    (rec,) = r.buffer.records(CircuitTrace)
    assert [s for s, _ in rec.stages] == ["submit", "kernel_start", "complete"]
    with pytest.raises(ValueError):
        ObservabilityConfig(stages=("submit", "bogus"))


def test_worker_timeline_accounting():
    tl = WorkerTimeline("w1")
    tl.record(0.0, 1.0, "batch")
    tl.record(2.0, 3.0, "spill")
    s = tl.summary()
    assert s["busy_s"] == pytest.approx(2.0)
    assert s["spill_s"] == pytest.approx(1.0)
    assert s["idle_s"] == pytest.approx(1.0)
    assert s["utilization"] == pytest.approx(2.0 / 3.0, abs=1e-3)
    assert s["by_kind"] == {"batch": 1, "spill": 1}


# ------------------------------------------------ simulation trace (golden)
def _seeded_sim_trace() -> dict:
    """4-tenant virtual-clock gateway run with a mid-run worker crash (and
    recovery), so the golden trace covers the failure-recovery stages;
    everything deterministic."""
    from repro.comanager.simulation import SystemSimulation, homogeneous_workers
    from repro.comanager.tenancy import JobSpec

    workers = homogeneous_workers(3, 10)
    jobs = [
        JobSpec("alice", qc=5, n_layers=1, n_circuits=30, submit_time=0.0),
        JobSpec("bob", qc=5, n_layers=1, n_circuits=30, submit_time=0.0),
        JobSpec("carol", qc=7, n_layers=1, n_circuits=20, submit_time=0.5),
        JobSpec("dave", qc=7, n_layers=1, n_circuits=20, submit_time=0.5),
    ]
    sim = SystemSimulation(
        workers,
        jobs,
        gateway=True,
        gateway_deadline=0.2,
        heartbeat_period=0.5,
        tenant_slos_ms={"alice": 2000.0, "carol": 2000.0},
        worker_failures={
            "w1": {"kind": "crash_recover", "at": 0.3, "recover_at": 3.0}
        },
    )
    report = sim.run()
    assert report.trace is not None
    assert report.trace.open_traces == 0  # every span closed
    records = report.trace.buffer.records(CircuitTrace)
    assert validate_trace(records) == []
    # the injected crash produced real recovery traffic: batches lost on w1
    # went back through the coalescer and completed elsewhere (or on the
    # recovered worker) — every circuit still ends in "complete" below
    assert any(
        stage == "requeue" for r in records for stage, _ in r.stages
    ), "crash_recover schedule produced no requeue stage"
    return report.trace.export_chrome_trace()


def _dump(trace: dict) -> str:
    return json.dumps(trace, indent=1, sort_keys=True)


def test_simulation_trace_matches_golden_snapshot():
    """Same seed/jobs -> bit-identical Chrome trace (virtual clock floats
    are IEEE-deterministic).  Regenerate intentionally with
    ``PYTHONPATH=src python tests/test_observability.py --update``."""
    got = _dump(_seeded_sim_trace())
    assert TRACE_SNAPSHOT.exists(), (
        "missing golden trace; generate with "
        "`PYTHONPATH=src python tests/test_observability.py --update`"
    )
    assert got == TRACE_SNAPSHOT.read_text(), (
        "exported Chrome trace drifted from tests/snapshots/gateway_trace.json; "
        "if intentional, regenerate with "
        "`PYTHONPATH=src python tests/test_observability.py --update`"
    )


def test_simulation_trace_covers_every_circuit():
    trace = _seeded_sim_trace()
    events = trace["traceEvents"]
    tenant_pids = {
        e["pid"]
        for e in events
        if e["ph"] == "M"
        and e["name"] == "process_name"
        and e["args"]["name"].startswith("tenant ")
    }
    worker_pids = {
        e["pid"]
        for e in events
        if e["ph"] == "M"
        and e["name"] == "process_name"
        and e["args"]["name"].startswith("worker ")
    }
    assert len(tenant_pids) == 4  # one row per tenant
    assert worker_pids  # and per executing worker
    begins = {e["id"] for e in events if e["ph"] == "b" and e["cat"] == "circuit"}
    ends = {e["id"] for e in events if e["ph"] == "e" and e["cat"] == "circuit"}
    assert begins == ends  # no orphan spans
    assert len(begins) == 100  # submit -> complete for every circuit


# --------------------------------------------- real dispatcher well-formed
def test_real_dispatcher_trace_well_formed():
    import jax.numpy as jnp

    from repro.core.circuits import build_quclassi_circuit
    from repro.serve.dispatcher import GatewayRuntime

    spec = build_quclassi_circuit(5, 1)
    rng = np.random.default_rng(0)

    def fake_kernel(spec_, theta, data):
        return jnp.zeros(theta.shape[0])

    with GatewayRuntime(
        mode="async", deadline=0.02, kernel=fake_kernel
    ) as rt:
        run_a = rt.executor(spec, "alice", slo_ms=10_000.0)
        run_b = rt.executor(spec, "bob")
        theta = rng.normal(size=(5, spec.n_theta)).astype(np.float32)
        data = rng.normal(size=(5, spec.n_data)).astype(np.float32)
        run_a(theta, data)
        run_b(theta, data)
        tr = rt.telemetry.trace
        assert tr.open_traces == 0
        records = tr.buffer.records(CircuitTrace)
        assert len(records) == 10
        assert validate_trace(records) == []
        for rec in records:
            assert rec.outcome == "complete"
            assert rec.worker is not None
        assert tr.timelines  # worker occupancy captured
        summary = rt.telemetry.summary()["observability"]
        assert summary["stages"]["e2e"]["count"] == 10


def test_eviction_spans_closed():
    """Evicted circuits close their trace with outcome='evict'."""
    from repro.serve.gateway import Gateway

    gw = Gateway(deadline=0.01, target_lanes=None)
    gw.register_client("a", slo_ms=1.0)
    fut = gw.submit("a", "k", None, now=0.0)
    (batch,) = gw.flush(now=5.0)  # SLO long gone
    gw.evict(batch, now=5.0)
    with pytest.raises(Exception):
        fut.value
    tr = gw.telemetry.trace
    assert tr.open_traces == 0
    (rec,) = tr.buffer.records(CircuitTrace)
    assert rec.outcome == "evict"
    assert rec.stages[-1][0] == "evict"
    assert validate_trace([rec]) == []


def test_reject_records_closed_trace():
    from repro.serve.gateway import Backpressure, Gateway

    gw = Gateway(max_pending=1)
    gw.register_client("a")
    gw.submit("a", "k", None, now=0.0)
    with pytest.raises(Backpressure):
        gw.submit("a", "k", None, now=0.1)
    rejects = [
        r for r in gw.telemetry.trace.buffer.records(CircuitTrace)
        if r.outcome == "reject"
    ]
    assert len(rejects) == 1


# ----------------------------------------------------- service-model summary
def test_service_model_in_telemetry_summary():
    """Satellite: EWMA seconds-per-unit and prediction error are surfaced."""
    from repro.core.circuits import build_quclassi_circuit
    from repro.serve.metrics import Telemetry

    t = Telemetry()
    spec = build_quclassi_circuit(5, 1)
    t.service.update(spec, 100.0, 2.0)
    t.service.update(spec, 100.0, 3.0)
    sm = t.summary()["service_model"]
    assert sm["alpha"] == 0.25
    assert sm["global_s_per_unit"] is not None
    (label, entry), = sm["per_key"].items()
    assert entry["updates"] == 2
    assert entry["s_per_unit"] > 0
    # second update's prediction (0.02 s/u * 100 = 2 s) vs measured 3 s
    assert sm["ewma_rel_error"] == pytest.approx(1.0 / 3.0, abs=1e-3)


def test_kernel_launch_observer():
    """ops.set_launch_observer reports shift_execution_info per launch."""
    import jax.numpy as jnp

    from repro.core.circuits import build_quclassi_circuit
    from repro.kernels import ops as kops

    spec = build_quclassi_circuit(5, 1)
    theta = jnp.zeros((2, spec.n_theta))
    data = jnp.zeros((2, spec.n_data))
    seen = []
    prev = kops.set_launch_observer(seen.append)
    try:
        kops.vqc_fidelity_shiftgroups(spec, theta, data, False, (0,))
        kops.vqc_fidelity_shiftgroups(spec, theta, data, False, (0,))
    finally:
        kops.set_launch_observer(prev)
    assert len(seen) == 2  # fires per call, not per jit trace
    info = seen[0]
    assert info["mode"] in ("fused", "spill", "materialize")
    assert info["lanes"] == 2
    assert info["banks"] == 1
    assert info["vmem_bytes"] > 0


def test_spill_launch_observer_emits_per_tile_events():
    """On the spill path the observer fires once per depth-tile launch
    segment after the summary event, exposing the double-buffered backward
    sweep: deepest-first tile order, ping-pong buffer alternation, and
    which fetches overlapped the previous tile's compute."""
    from repro.core.circuits import build_quclassi_circuit
    from repro.kernels import ops as kops
    from repro.kernels import vqc_statevector as K

    spec = build_quclassi_circuit(17, 3)  # m = 8: spills at TB = 512
    info = K.shift_execution_info(spec, 512)
    assert info["mode"] == "spill" and info["n_tiles"] > 1
    seen = []
    prev = kops.set_launch_observer(seen.append)
    try:
        # the emission helper the public wrappers call per launch; driving
        # it directly keeps the test free of a 512-lane m = 8 execution
        kops._notify_launch(spec, 512, False, None)
    finally:
        kops.set_launch_observer(prev)
    assert len(seen) == info["launches"]  # summary + one per tile
    summary, tiles = seen[0], seen[1:]
    assert summary["mode"] == "spill"
    assert len(tiles) == info["n_tiles"]
    for order, ev in enumerate(tiles):
        assert ev["mode"] == "spill_tile"
        assert ev["tile_order"] == order
        assert ev["tile"] == info["n_tiles"] - 1 - order  # deepest-first
        assert ev["buffer"] == order % 2                  # ping-pong
        assert ev["overlapped"] == (order > 0)
        assert ev["boundary_bytes"] == info["spill_buffer_bytes"]
        assert ev["lanes"] == 512 and ev["banks"] == 1


def test_kernel_span_args_spill_metadata():
    """Trace spans of spilled shift batches carry the boundary-fetch shape
    (buffer bytes, fetch count, overlap ratio) so Perfetto shows the DMA
    overlap; fused batches carry none of it."""
    import jax.numpy as jnp

    from repro.core import shift_rule
    from repro.core.circuits import build_quclassi_circuit
    from repro.serve import ShiftGroupKey
    from repro.serve.coalescer import CoalescedBatch, PendingCircuit
    from repro.serve.dispatcher import kernel_span_args

    def shift_batch(spec, b):
        theta = jnp.zeros((spec.n_theta,), jnp.float32)
        data = jnp.zeros((b, spec.n_data), jnp.float32)
        bank = shift_rule.build_shift_bank(theta, data)
        key = ShiftGroupKey(spec, False)
        members = [
            PendingCircuit(key, "t", g, 0.0, (bank, g), lanes=b)
            for g in range(bank.n_groups)
        ]
        return CoalescedBatch(key=key, members=members, created=0.0)

    wide = build_quclassi_circuit(17, 3)
    args = kernel_span_args(shift_batch(wide, 512))
    assert args["kind"] == "shift" and args["mode"] == "spill"
    assert args["boundary_fetches"] == args["n_tiles"] > 1
    assert args["launches"] == args["n_tiles"] + 1
    assert args["spill_buffer_bytes"] > 0
    assert 0 < args["overlap_ratio"] < 1
    # footprint already includes the second ping-pong boundary buffer
    assert args["vmem_bytes"] > args["spill_buffer_bytes"]

    narrow = build_quclassi_circuit(5, 1)
    fused = kernel_span_args(shift_batch(narrow, 8))
    assert fused["mode"] == "fused"
    for k in ("spill_buffer_bytes", "boundary_fetches", "overlap_ratio"):
        assert k not in fused


if __name__ == "__main__":
    import sys

    if "--update" in sys.argv:
        TRACE_SNAPSHOT.write_text(_dump(_seeded_sim_trace()))
        print(f"updated {TRACE_SNAPSHOT}")
    else:
        print(_dump(_seeded_sim_trace())[:2000])
