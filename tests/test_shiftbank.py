"""Shift-structured circuit-bank execution: implicit ``ShiftBank``s, the
prefix-reuse kernel, group-scheduled data-plane executors, and the serving
gateway's per-(param, shift)-group path.

Correctness contract: everything here must agree with the MATERIALIZED bank
(``build_bank`` + the standard fused kernel / dense-sim oracle) — scheduling
and the shift-structured execution strategy never change the math.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import circuits, shift_rule
from repro.core.sim import CircuitSpec, Op
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels import vqc_statevector as K


def _setup(qc, nl, b=3, seed=0):
    spec = circuits.build_quclassi_circuit(qc, nl)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, (spec.n_theta,), jnp.float32,
                               minval=0.0, maxval=np.pi)
    data = jax.random.uniform(jax.random.fold_in(key, 1), (b, spec.n_data),
                              jnp.float32, minval=0.0, maxval=np.pi)
    return spec, theta, data


# ------------------------------------------------------------ ShiftBank
@pytest.mark.parametrize("qc,nl", [(5, 1), (5, 3), (7, 1), (7, 3)])
@pytest.mark.parametrize("four_term", [False, True])
@pytest.mark.parametrize("seed", [0, 7])
def test_materialize_reproduces_build_bank_exactly(qc, nl, four_term, seed):
    """The escape hatch is BIT-identical to build_bank, not just close."""
    spec, theta, data = _setup(qc, nl, b=4, seed=seed)
    implicit = shift_rule.build_shift_bank(theta, data, four_term=four_term)
    explicit = shift_rule.build_bank(theta, data, four_term=four_term)
    mat = implicit.materialize()
    assert np.array_equal(np.asarray(mat.theta), np.asarray(explicit.theta))
    assert np.array_equal(np.asarray(mat.data), np.asarray(explicit.data))
    assert (mat.n_samples, mat.n_params, mat.four_term) == \
        (explicit.n_samples, explicit.n_params, explicit.four_term)


def test_shiftbank_bookkeeping_matches_circuitbank():
    spec, theta, data = _setup(5, 2, b=3)
    bank = shift_rule.build_shift_bank(theta, data)
    assert bank.n_groups == 1 + 2 * spec.n_theta
    assert bank.n_circuits == bank.n_groups * 3
    f = jnp.arange(bank.n_circuits, dtype=jnp.float32)
    for got, want in zip(bank.split_results(f),
                         bank.materialize().split_results(f)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    descs = bank.group_descriptors()
    assert descs[0] == (-1, 0.0)
    assert len(descs) == bank.n_groups
    assert descs[1][0] == 0 and descs[1][1] == pytest.approx(np.pi / 2)
    assert descs[1 + spec.n_theta][1] == pytest.approx(-np.pi / 2)


def test_per_sample_theta_shiftbank():
    """ShiftBank generalizes build_bank: per-sample base thetas are allowed."""
    spec, _, data = _setup(5, 1, b=4)
    theta = jax.random.uniform(jax.random.PRNGKey(3), (4, spec.n_theta),
                               jnp.float32, minval=0.0, maxval=np.pi)
    bank = shift_rule.build_shift_bank(theta, data)
    mat = bank.materialize()
    j, b = 2, 1
    row = np.asarray(mat.theta[4 + j * 4 + b])
    expect = np.asarray(theta[b]).copy()
    expect[j] += np.pi / 2
    np.testing.assert_allclose(row, expect, atol=1e-6)
    got = kops.vqc_fidelity_shiftbank(spec, bank.theta, bank.data)
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------- prefix-reuse kernel
@pytest.mark.parametrize("qc", [5, 7])
@pytest.mark.parametrize("nl", [1, 3])
@pytest.mark.parametrize("four_term", [False, True])
def test_prefix_reuse_matches_ref(qc, nl, four_term):
    spec, theta, data = _setup(qc, nl, b=3, seed=qc * 10 + nl)
    bank = shift_rule.build_shift_bank(theta, data, four_term=four_term)
    mat = bank.materialize()
    got = kops.vqc_fidelity_shiftbank(spec, bank.theta, bank.data, four_term)
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data)
    assert got.shape == (bank.n_circuits,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_group_subset_matches_full():
    spec, theta, data = _setup(5, 3, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    full = np.asarray(kops.vqc_fidelity_shiftgroups(spec, bank.theta,
                                                    bank.data))
    groups = (0, 2, 5, bank.n_groups - 1)
    sub = np.asarray(kops.vqc_fidelity_shiftgroups(spec, bank.theta,
                                                   bank.data, False, groups))
    np.testing.assert_allclose(sub, full[list(groups)], atol=1e-6)


def test_shift_plan_structure():
    spec = circuits.build_quclassi_circuit(7, 3)
    plan = K.build_shift_plan(spec)
    assert plan is not None
    m = (7 - 1) // 2
    assert plan.m == m
    assert len(plan.data_ops) == spec.n_data
    assert len(plan.train_ops) == spec.n_theta
    # every parameter has a unique dependent gate, in circuit order
    assert plan.theta_pos == tuple(range(spec.n_theta))


def test_shift_plan_rejects_unstructured_circuits():
    # no SWAP-test tail -> no product structure to exploit
    spec = CircuitSpec(n_qubits=2, ops=(Op("ry", (0,), ("theta", 0)),
                                        Op("ry", (1,), ("data", 0))),
                       n_theta=1, n_data=1)
    assert K.build_shift_plan(spec) is None
    # fallback path still produces correct bank fidelities
    theta = jnp.asarray([[0.3], [0.9]], jnp.float32)
    data = jnp.asarray([[0.1], [0.4]], jnp.float32)
    bank = shift_rule.build_shift_bank(theta, data)
    got = kops.vqc_fidelity_shiftbank(spec, bank.theta, bank.data)
    mat = bank.materialize()
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_shift_bank_stats_acceptance_ratios():
    """The paper's 7q/3l config: >=5x fewer gate applications, >=10x fewer
    angle bytes than the materialized bank (ISSUE acceptance)."""
    spec = circuits.build_quclassi_circuit(7, 3)
    stats = K.shift_bank_stats(spec, n_samples=64)
    assert stats["gate_apps_ratio"] >= 5.0
    assert stats["angle_bytes_ratio"] >= 10.0


# -------------------------------------------- descending two-qubit pairs
def test_rot2_descending_symmetric_pairs():
    """RYY/RZZ are symmetric under qubit exchange; the kernel now accepts
    descending pairs instead of raising (satellite fix)."""
    ops_desc = (Op("ry", (0,), ("data", 0)), Op("ryy", (1, 0), ("theta", 0)),
                Op("rzz", (2, 1), ("theta", 1)))
    ops_asc = (Op("ry", (0,), ("data", 0)), Op("ryy", (0, 1), ("theta", 0)),
               Op("rzz", (1, 2), ("theta", 1)))
    sd = CircuitSpec(n_qubits=3, ops=ops_desc, n_theta=2, n_data=1)
    sa = CircuitSpec(n_qubits=3, ops=ops_asc, n_theta=2, n_data=1)
    theta = jnp.asarray([[0.7, 1.1], [0.2, 2.0]], jnp.float32)
    data = jnp.asarray([[0.5], [1.3]], jnp.float32)
    re_d, im_d = kops.vqc_state(sd, theta, data)
    re_a, im_a = kops.vqc_state(sa, theta, data)
    np.testing.assert_allclose(np.asarray(re_d), np.asarray(re_a), atol=1e-6)
    np.testing.assert_allclose(np.asarray(im_d), np.asarray(im_a), atol=1e-6)
    # and against the dense-sim oracle, which permutes axes generically
    re_r, im_r = ref.vqc_state_ref(sd, theta, data)
    np.testing.assert_allclose(np.asarray(re_d), np.asarray(re_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(im_d), np.asarray(im_r), atol=1e-5)


def test_rot2_descending_controlled_still_raises():
    ops_bad = (Op("cry", (1, 0), ("theta", 0)),)
    spec = CircuitSpec(n_qubits=2, ops=ops_bad, n_theta=1, n_data=0)
    theta = jnp.asarray([[0.7]], jnp.float32)
    data = jnp.zeros((1, 0), jnp.float32)
    with pytest.raises(NotImplementedError):
        kops.vqc_state(spec, theta, data)


# -------------------------------------------------- gradient equivalence
@pytest.mark.parametrize("qc,nl,exact", [(5, 1, False), (5, 3, True),
                                         (7, 2, False)])
def test_parameter_shift_grad_implicit_vs_materialized(qc, nl, exact):
    spec, theta, data = _setup(qc, nl, b=3, seed=nl)
    labels = jnp.asarray([0.0, 1.0, 1.0])
    l_mat, g_mat, f_mat = shift_rule.parameter_shift_grad(
        spec, theta, data, labels, exact_controlled=exact)
    l_imp, g_imp, f_imp = shift_rule.parameter_shift_grad(
        spec, theta, data, labels, executor=kops.shiftbank_executor(spec),
        exact_controlled=exact)
    np.testing.assert_allclose(float(l_imp), float(l_mat), atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_imp), np.asarray(g_mat), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_imp), np.asarray(f_mat), atol=1e-5)


def test_implicit_flag_with_shift_unaware_executor():
    """implicit=True + a plain (theta, data) executor goes through
    materialize() — the compatibility escape hatch."""
    spec, theta, data = _setup(5, 1, b=2)
    labels = jnp.asarray([1.0, 0.0])
    seen = {}

    def executor(t, d):
        seen["shape"] = (t.shape, d.shape)
        from repro.core import fidelity as fid
        return fid.fidelity_batch(spec, t, d)

    l1, g1, _ = shift_rule.parameter_shift_grad(spec, theta, data, labels,
                                                executor=executor,
                                                implicit=True)
    c = 2 * (2 * spec.n_theta + 1)
    assert seen["shape"] == ((c, spec.n_theta), (c, spec.n_data))
    l0, g0, _ = shift_rule.parameter_shift_grad(spec, theta, data, labels)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0), atol=1e-6)


# ------------------------------------------------- data-plane executors
def test_worker_batched_group_assignment():
    from repro.comanager import dataplane
    spec, theta, data = _setup(5, 2, b=5)
    bank = shift_rule.build_shift_bank(theta, data)
    assignment = dataplane.round_robin_assignment(bank.n_groups, 3)
    run = dataplane.worker_batched_executor(spec, assignment, 3)
    assert run.accepts_shiftbank
    got = run(bank)
    mat = bank.materialize()
    want = kops.vqc_fidelity(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_worker_batched_row_assignment_accepts_implicit_bank():
    """Legacy per-row assignments still work on implicit banks (materialize
    fallback preserves exact per-worker row placement)."""
    from repro.comanager import dataplane
    spec, theta, data = _setup(5, 1, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    assignment = dataplane.round_robin_assignment(bank.n_circuits, 2)
    run = dataplane.worker_batched_executor(spec, assignment, 2)
    mat = bank.materialize()
    np.testing.assert_allclose(np.asarray(run(bank)),
                               np.asarray(run(mat.theta, mat.data)),
                               atol=1e-6)


def test_worker_batched_bad_assignment_length():
    from repro.comanager import dataplane
    spec, theta, data = _setup(5, 1, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    run = dataplane.worker_batched_executor(spec, [0, 1], 2)
    with pytest.raises(ValueError, match="groups"):
        run(bank)


def test_sharded_executor_accepts_implicit_bank():
    from repro.comanager import dataplane
    from repro.launch.mesh import make_host_mesh
    spec, theta, data = _setup(5, 2, b=5)    # odd B exercises sample padding
    bank = shift_rule.build_shift_bank(theta, data)
    run = dataplane.sharded_executor(spec, make_host_mesh())
    got = run(bank)
    assert got.shape == (bank.n_circuits,)
    mat = bank.materialize()
    want = kops.vqc_fidelity(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------------ serving gateway
def test_gateway_shift_executor_matches_materialized():
    from repro.serve import GatewayRuntime, ShiftGroupKey
    spec, theta, data = _setup(5, 2, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    rt = GatewayRuntime()
    run = rt.shift_executor(spec, "tenant-a")
    assert run.accepts_shiftbank
    got = run(bank)
    mat = bank.materialize()
    want = kops.vqc_fidelity(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # groups were dispatched as shift-group batches, not per-row circuits
    assert rt.dispatcher.batch_log, "no batches executed"
    total_members = sum(n for (_, n, _) in rt.dispatcher.batch_log)
    assert total_members == bank.n_groups
    # lane-fill telemetry counts the kernel lanes the groups occupy
    # (n_groups * B sample lanes), not the group-subtask member count,
    # and pays per-group row padding (each group pads its B samples
    # independently in the kernel launch)
    assert rt.telemetry.batched_circuits == bank.n_groups * bank.n_samples
    import math
    per_group = math.ceil(bank.n_samples / rt.gateway.coalescer.lanes) * \
        rt.gateway.coalescer.lanes
    assert rt.telemetry.padded_lanes == bank.n_groups * per_group


def test_shift_executors_accept_materialized_banks():
    """Shift-aware executors still take plain (theta, data) calls, so
    bank_mode='materialized' composes with them instead of crashing."""
    from repro.serve import GatewayRuntime
    spec, theta, data = _setup(5, 1, b=3)
    bank = shift_rule.build_shift_bank(theta, data)
    mat = bank.materialize()
    want = np.asarray(kops.vqc_fidelity(spec, mat.theta, mat.data))
    np.testing.assert_allclose(
        np.asarray(kops.shiftbank_executor(spec)(mat.theta, mat.data)),
        want, atol=1e-6)
    rt = GatewayRuntime()
    run = rt.shift_executor(spec, "tenant-a")
    np.testing.assert_allclose(np.asarray(run(mat.theta, mat.data)), want,
                               atol=1e-5)
    # and run_bank routes a materialized CircuitBank through the same path
    np.testing.assert_allclose(
        np.asarray(shift_rule.run_bank(run, mat)), want, atol=1e-5)


def test_gateway_shift_groups_coalesce_across_same_spec_banks():
    """Keys are structural: group subtasks of DIFFERENT banks of the same
    spec + shift rule share a key (they fuse into one multi-bank launch);
    different specs or shift rules never share one."""
    from repro.serve import ShiftGroupKey
    spec, theta, data = _setup(5, 1, b=2)
    other = circuits.build_quclassi_circuit(5, 2)
    assert ShiftGroupKey(spec, False) == ShiftGroupKey(spec, False)
    assert ShiftGroupKey(spec, False) != ShiftGroupKey(spec, True)
    assert ShiftGroupKey(spec, False) != ShiftGroupKey(other, False)


def test_gateway_fuses_same_spec_banks_into_one_launch():
    """Two tenants' banks of one spec coalesce into multi-bank launches:
    fewer kernel launches than banks, results bit-identical to the per-bank
    implicit path."""
    from repro.serve import GatewayRuntime
    spec, theta_a, data = _setup(5, 2, b=4)
    theta_b = theta_a + 0.3
    bank_a = shift_rule.build_shift_bank(theta_a, data)
    bank_b = shift_rule.build_shift_bank(theta_b, data)
    rt = GatewayRuntime(deadline=30.0)
    rt.gateway.register_client("tenant-a")
    rt.gateway.register_client("tenant-b")
    # submit both banks' group subtasks before any drain: one shared buffer
    from repro.serve import ShiftGroupKey
    key = ShiftGroupKey(spec, False)
    futs = []
    for bank in (bank_a, bank_b):
        for g in range(bank.n_groups):
            futs.append(rt.gateway.submit(
                "tenant-a" if bank is bank_a else "tenant-b", key, (bank, g),
                now=rt.dispatcher.clock(), lanes=bank.n_samples))
    rt.dispatcher.drain()
    n = bank_a.n_groups
    got_a = jnp.concatenate([f.value for f in futs[:n]])
    got_b = jnp.concatenate([f.value for f in futs[n:]])
    want_a = kops.vqc_fidelity_shiftbank(spec, bank_a.theta, bank_a.data)
    want_b = kops.vqc_fidelity_shiftbank(spec, bank_b.theta, bank_b.data)
    assert np.array_equal(np.asarray(got_a), np.asarray(want_a))
    assert np.array_equal(np.asarray(got_b), np.asarray(want_b))
    # both banks rode ONE fused launch (2 banks, 1 kernel call)
    assert rt.telemetry.fused_launches == 1
    assert rt.telemetry.fused_banks == 2
    assert rt.telemetry.multibank_launches == 1
    assert len(rt.dispatcher.batch_log) == 1


def test_coalescer_lane_target_flushes_multilane_buffers():
    """target_lanes: a buffer of few multi-lane members (shift-group
    subtasks) size-flushes once its occupied kernel lanes hit the target,
    without waiting for `target` members or the deadline."""
    from repro.serve.coalescer import Coalescer, PendingCircuit
    co = Coalescer(target=128, lanes=128, deadline=100.0, target_lanes=256)
    out = []
    for i in range(5):
        out += co.add(PendingCircuit(key="k", client_id="c", seq=i,
                                     arrival=0.0, payload=None, lanes=64))
    # members 1-4 reach 256 lanes -> one size-triggered batch of 4
    assert len(out) == 1 and out[0].n == 4
    assert co.buffered == 1


def test_grad_shift_through_gateway_shift_executor():
    from repro.core import quclassi
    from repro.core.quclassi import QuClassiConfig
    from repro.data import mnist
    from repro.serve import GatewayRuntime
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(3, 9, n_per_class=4, seed=0)
    x, y = jnp.asarray(x[:3]), jnp.asarray(y[:3])
    params = quclassi.init_params(cfg, jax.random.PRNGKey(0))
    rt = GatewayRuntime()
    ex = rt.shift_executor(cfg.spec, "trainer")
    l_gw, g_gw, _ = quclassi.grad_shift(cfg, params, x, y, executor=ex)
    l_ref, g_ref, _ = quclassi.grad_shift(cfg, params, x, y)
    np.testing.assert_allclose(float(l_gw), float(l_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_gw["theta"]),
                               np.asarray(g_ref["theta"]), atol=1e-5)


def test_gateway_shift_keys_do_not_leak_coalescer_buffers():
    """Every bank submission mints a fresh ShiftGroupKey; emptied buffers
    must be retired or a long training run grows the coalescer forever."""
    from repro.serve import GatewayRuntime
    spec, theta, data = _setup(5, 1, b=2)
    rt = GatewayRuntime()
    run = rt.shift_executor(spec, "tenant-a")
    for i in range(5):
        run(shift_rule.build_shift_bank(theta + 0.01 * i, data))
    assert len(rt.gateway.coalescer._buffers) == 0


def test_dispatcher_shift_kernel_injectable():
    """GatewayRuntime(shift_kernel=...) substitutes the shift-group runner,
    mirroring the documented KernelFn substitution point."""
    from repro.serve import GatewayRuntime
    spec, theta, data = _setup(5, 1, b=3)
    bank = shift_rule.build_shift_bank(theta, data)
    calls = []

    def stub(s, t, d, four_term, groups):
        calls.append(groups)
        return kops.vqc_fidelity_shiftgroups(s, t, d, four_term, groups)

    rt = GatewayRuntime(shift_kernel=stub)
    run = rt.shift_executor(spec, "tenant-a")
    got = run(bank)
    assert calls and sorted(g for gs in calls for g in gs) == \
        list(range(bank.n_groups))
    mat = bank.materialize()
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(kops.vqc_fidelity(spec, mat.theta,
                                                      mat.data)), atol=1e-5)


# -------------------------------------------------- fused multi-bank kernel
def _banks(spec, k, b=3, seed=0, four_term=False):
    key = jax.random.PRNGKey(seed)
    banks = []
    for i in range(k):
        theta = jax.random.uniform(jax.random.fold_in(key, i),
                                   (spec.n_theta,), jnp.float32,
                                   minval=0.0, maxval=np.pi)
        data = jax.random.uniform(jax.random.fold_in(key, 100 + i),
                                  (b + i, spec.n_data), jnp.float32,
                                  minval=0.0, maxval=np.pi)
        banks.append(shift_rule.build_shift_bank(theta, data,
                                                 four_term=four_term))
    return banks


@pytest.mark.parametrize("qc,nl", [(5, 1), (7, 3)])
def test_multibank_kernel_bit_identical_to_per_bank(qc, nl):
    """K same-spec banks fused into one launch: per-bank blocks are
    BIT-identical to K separate prefix-reuse launches (per-lane math is
    untouched by lane packing)."""
    spec = circuits.build_quclassi_circuit(qc, nl)
    banks = _banks(spec, 3, seed=qc)
    outs = kops.vqc_fidelity_shiftgroups_multibank(
        spec, tuple(b.theta for b in banks), tuple(b.data for b in banks),
        False, tuple(tuple(range(b.n_groups)) for b in banks))
    for bank, out in zip(banks, outs):
        ref = kops.vqc_fidelity_shiftgroups(spec, bank.theta, bank.data)
        assert out.shape == ref.shape
        assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_multibank_kernel_partial_group_sets():
    """Banks may request different group subsets; each gets exactly its
    rows, pulled from the union-group fused launch."""
    spec = circuits.build_quclassi_circuit(5, 2)
    banks = _banks(spec, 2)
    gs = ((0, 2, 5), (1, 2, spec.n_theta * 2))
    outs = kops.vqc_fidelity_shiftgroups_multibank(
        spec, tuple(b.theta for b in banks), tuple(b.data for b in banks),
        False, gs)
    for bank, got, groups in zip(banks, outs, gs):
        want = kops.vqc_fidelity_shiftgroups(spec, bank.theta, bank.data,
                                             False, groups)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6)


def test_multibank_fallback_for_unstructured_spec():
    """No SWAP-test product structure -> per-bank materialized fallback,
    same results (not fused, still correct)."""
    spec = CircuitSpec(n_qubits=2, ops=(Op("ry", (0,), ("theta", 0)),
                                        Op("ry", (1,), ("data", 0))),
                       n_theta=1, n_data=1)
    t1 = jnp.asarray([[0.3], [0.9]], jnp.float32)
    t2 = jnp.asarray([[1.1]], jnp.float32)
    d1 = jnp.asarray([[0.1], [0.4]], jnp.float32)
    d2 = jnp.asarray([[0.8]], jnp.float32)
    outs = kops.vqc_fidelity_shiftgroups_multibank(
        spec, (t1, t2), (d1, d2), False, ((0, 1, 2), (0, 1)))
    np.testing.assert_allclose(
        np.asarray(outs[0]),
        np.asarray(kops.vqc_fidelity_shiftgroups(spec, t1, d1, False,
                                                 (0, 1, 2))), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(outs[1]),
        np.asarray(kops.vqc_fidelity_shiftgroups(spec, t2, d2, False,
                                                 (0, 1))), atol=1e-6)


def test_group_bank_sets_and_run_bank_set():
    spec5 = circuits.build_quclassi_circuit(5, 1)
    spec7 = circuits.build_quclassi_circuit(7, 1)
    b5 = _banks(spec5, 2)
    b7 = _banks(spec7, 1)
    sets = shift_rule.group_bank_sets(
        [(spec5, b5[0]), (spec7, b7[0]), (spec5, b5[1])])
    assert set(sets) == {(spec5, False), (spec7, False)}
    assert sets[(spec5, False)] == b5
    # fused bank-set executor vs per-bank run_bank
    ex = kops.multibank_executor(spec5)
    assert ex.accepts_bankset
    fused = shift_rule.run_bank_set(ex, b5)
    plain = shift_rule.run_bank_set(kops.shiftbank_executor(spec5), b5)
    for f, p in zip(fused, plain):
        assert np.array_equal(np.asarray(f), np.asarray(p))


def test_worker_multibank_executor_matches_per_bank():
    """Fused multi-bank scheduling across workers: per-bank flat results
    match the materialized oracle for every bank in the set."""
    from repro.comanager import dataplane
    spec = circuits.build_quclassi_circuit(5, 2)
    banks = _banks(spec, 3)
    n_sub = sum(b.n_groups for b in banks)
    assignment = dataplane.round_robin_assignment(n_sub, 2)
    run = dataplane.worker_multibank_executor(spec, assignment, 2)
    assert run.accepts_bankset
    for bank, flat in zip(banks, run(banks)):
        mat = bank.materialize()
        want = kops.vqc_fidelity(spec, mat.theta, mat.data)
        np.testing.assert_allclose(np.asarray(flat), np.asarray(want),
                                   atol=1e-5)


def test_worker_multibank_executor_validates():
    from repro.comanager import dataplane
    spec = circuits.build_quclassi_circuit(5, 1)
    banks = _banks(spec, 2)
    run = dataplane.worker_multibank_executor(spec, [0, 1], 2)
    with pytest.raises(ValueError, match="subtasks"):
        run(banks)


def test_sharded_executor_run_banks():
    """The mesh-sharded fused multi-bank path (the dispatcher's spill
    executor) agrees with the local fused kernel."""
    from repro.comanager import dataplane
    from repro.launch.mesh import make_host_mesh
    spec = circuits.build_quclassi_circuit(5, 2)
    banks = _banks(spec, 2)
    gs = tuple(tuple(range(b.n_groups)) for b in banks)
    args = (tuple(b.theta for b in banks), tuple(b.data for b in banks))
    run = dataplane.sharded_executor(spec, make_host_mesh())
    got = run.run_banks(*args, False, gs)
    want = kops.vqc_fidelity_shiftgroups_multibank(spec, *args, False, gs)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)


# ------------------------------------------- VMEM-aware checkpoint spilling
def test_wide_register_selects_spill_fast_path():
    """m = 8 at the production tile (TB = 512): the checkpoint set exceeds
    the VMEM budget and the planner selects depth-tiled spilling — the
    prefix-reuse fast path, NOT materialize()."""
    spec = circuits.build_quclassi_circuit(17, 3)     # m = 8
    assert K.build_shift_plan(spec) is not None       # fast path applies
    info = K.shift_execution_info(spec, 512)
    assert info["mode"] == "spill"
    assert info["n_tiles"] > 1
    assert info["launches"] == info["n_tiles"] + 1
    # the reported footprint includes the SECOND ping-pong boundary buffer
    # of the double-buffered backward launch (exactly one register state);
    # tiling itself still budgets without it — the nominal budget reserves
    # the double-buffering headroom below physical VMEM.
    assert info["spill_buffer_bytes"] == K._state_bytes(8, 512)
    assert info["vmem_bytes"] - info["spill_buffer_bytes"] <= info["vmem_budget"]
    assert 0 < info["overlap_ratio"] < 1
    # the paper's narrow registers stay on the single-sweep path
    narrow = K.shift_execution_info(circuits.build_quclassi_circuit(7, 3),
                                    512)
    assert narrow["mode"] == "fused" and narrow["launches"] == 1


def test_spilled_execution_matches_single_sweep_m8():
    """Numeric agreement of the spilled path on a genuinely wide register
    (m = 8, register-local states only — cheap): forced tiny budget vs the
    unconstrained single sweep."""
    spec = circuits.build_quclassi_circuit(17, 1)
    theta = jax.random.uniform(jax.random.PRNGKey(2), (spec.n_theta,),
                               jnp.float32, minval=0.0, maxval=np.pi)
    data = jax.random.uniform(jax.random.PRNGKey(3), (2, spec.n_data),
                              jnp.float32, minval=0.0, maxval=np.pi)
    bank = shift_rule.build_shift_bank(theta, data)
    plan = K.build_shift_plan(spec)
    budget = K.checkpoint_vmem_bytes(plan, 4, 128)    # fits ~4 checkpoints
    spilled = K.vqc_shift_fidelity(spec, bank.theta, bank.data,
                                   vmem_budget=budget)
    full = K.vqc_shift_fidelity(spec, bank.theta, bank.data)
    np.testing.assert_allclose(np.asarray(spilled), np.asarray(full),
                               atol=1e-5)


@pytest.mark.parametrize("four_term", [False, True])
def test_spilled_execution_matches_materialized(four_term):
    """Spill tiling vs the dense materialized oracle at a testable width."""
    spec = circuits.build_quclassi_circuit(7, 3)
    theta = jax.random.uniform(jax.random.PRNGKey(5), (spec.n_theta,),
                               jnp.float32, minval=0.0, maxval=np.pi)
    data = jax.random.uniform(jax.random.PRNGKey(6), (3, spec.n_data),
                              jnp.float32, minval=0.0, maxval=np.pi)
    bank = shift_rule.build_shift_bank(theta, data, four_term=four_term)
    plan = K.build_shift_plan(spec)
    budget = K.checkpoint_vmem_bytes(plan, 3, 128)
    tiles = K.plan_depth_tiles(plan, range(spec.n_theta), 128, budget)
    assert tiles is not None and len(tiles) > 1
    got = K.vqc_shift_fidelity(spec, bank.theta, bank.data,
                               four_term=four_term, vmem_budget=budget)
    mat = bank.materialize()
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data).reshape(
        bank.n_groups, bank.n_samples)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_spilled_group_subset():
    """Spilling composes with partial group requests (serving-path shape)."""
    spec = circuits.build_quclassi_circuit(5, 3)
    theta = jax.random.uniform(jax.random.PRNGKey(7), (spec.n_theta,),
                               jnp.float32, minval=0.0, maxval=np.pi)
    data = jax.random.uniform(jax.random.PRNGKey(8), (2, spec.n_data),
                              jnp.float32, minval=0.0, maxval=np.pi)
    groups = (0, 1, 4, 9, spec.n_theta * 2)
    plan = K.build_shift_plan(spec)
    budget = K.checkpoint_vmem_bytes(plan, 2, 128)
    got = K.vqc_shift_fidelity(spec, theta[None].repeat(2, 0), data,
                               groups=groups, vmem_budget=budget)
    want = K.vqc_shift_fidelity(spec, theta[None].repeat(2, 0), data,
                                groups=groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_plan_depth_tiles_boundaries():
    spec = circuits.build_quclassi_circuit(7, 3)
    plan = K.build_shift_plan(spec)
    assert K.plan_depth_tiles(plan, range(spec.n_theta), 128,
                              K.VMEM_BUDGET_BYTES) is None   # narrow: fits
    budget = K.checkpoint_vmem_bytes(plan, 3, 128)
    tiles = K.plan_depth_tiles(plan, range(spec.n_theta), 128, budget)
    # tiles partition [first_pos, n_train) contiguously, ascending
    assert tiles[0][0] == 0 and tiles[-1][1] == len(plan.train_ops)
    for (a, b), (c, d) in zip(tiles, tiles[1:]):
        assert b == c and a < b


def test_trainer_bank_mode_validation():
    from repro.core import trainer
    from repro.core.quclassi import QuClassiConfig
    with pytest.raises(ValueError, match="bank_mode"):
        trainer.train(QuClassiConfig(), (np.zeros((2, 8, 8)), np.zeros(2)),
                      (np.zeros((2, 8, 8)), np.zeros(2)),
                      epochs=0, bank_mode="bogus")


# ----------------------------------------- multi-use params: suffix replay
def _tied_setup(qc, nl, b=3, seed=0):
    spec = circuits.build_tied_quclassi_circuit(qc, nl)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, (spec.n_theta,), jnp.float32,
                               minval=0.0, maxval=np.pi)
    data = jax.random.uniform(jax.random.fold_in(key, 1), (b, spec.n_data),
                              jnp.float32, minval=0.0, maxval=np.pi)
    return spec, theta, data


def _deep_reuse_spec(r=20):
    """One parameter driving ``r`` consecutive gates on a 1-qubit register:
    the replay span covers the whole trainable stack, so a single-variant
    request is analytically cheaper to materialize."""
    body = [Op("rx", (1,), ("data", 0))]
    body += [Op("ry", (2,), ("theta", 0)) for _ in range(r)]
    tail = [Op("h", (0,)), Op("cswap", (0, 1, 2)), Op("h", (0,))]
    return CircuitSpec(n_qubits=3, ops=tuple(body + tail), n_theta=1,
                       n_data=1)


def test_tied_circuit_plan_structure():
    """2-reuse ansatz: every parameter drives two adjacent gates; the plan
    records the full position tuple and the legacy view exposes firsts."""
    spec = circuits.build_tied_quclassi_circuit(7, 3)
    assert spec.n_theta == circuits.build_quclassi_circuit(7, 3).n_theta
    plan = K.build_shift_plan(spec)
    assert plan is not None
    assert len(plan.train_ops) == 2 * spec.n_theta
    for j, ps in enumerate(plan.theta_positions):
        assert ps == (2 * j, 2 * j + 1)
        assert plan.replay_depth(j) == 2
    assert plan.theta_pos == tuple(2 * j for j in range(spec.n_theta))


@pytest.mark.parametrize("qc,nl", [(5, 2), (7, 3)])
@pytest.mark.parametrize("four_term", [False, True])
def test_multiuse_fused_matches_materialized(qc, nl, four_term):
    """Suffix-replay fidelities agree with the materialize() oracle."""
    spec, theta, data = _tied_setup(qc, nl, b=3, seed=qc + nl)
    assert K.use_shift_plan(spec, four_term)   # implicit path selected
    bank = shift_rule.build_shift_bank(theta, data, four_term=four_term)
    mat = bank.materialize()
    got = kops.vqc_fidelity_shiftgroups(spec, bank.theta, bank.data,
                                        four_term)
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data).reshape(
        bank.n_groups, bank.n_samples)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_multiuse_spilled_matches_materialized():
    """Forced tiny budget: replay spans spill-tile without splitting."""
    spec, theta, data = _tied_setup(5, 3, b=3, seed=9)
    plan = K.build_shift_plan(spec)
    bank = shift_rule.build_shift_bank(theta, data)
    budget = K.checkpoint_vmem_bytes(plan, 3, 128)
    anchors = sorted(ps[-1] for ps in plan.theta_positions)
    tiles = K.plan_depth_tiles(plan, anchors, 128, budget)
    assert tiles is not None and len(tiles) > 1
    got = K.vqc_shift_fidelity(spec, bank.theta, bank.data,
                               vmem_budget=budget)
    mat = bank.materialize()
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data).reshape(
        bank.n_groups, bank.n_samples)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_multiuse_multibank_matches_per_bank():
    spec, theta, data = _tied_setup(5, 2, b=3, seed=4)
    theta2 = theta + 0.1
    b1 = shift_rule.build_shift_bank(theta, data)
    b2 = shift_rule.build_shift_bank(theta2, data)
    gs = (tuple(range(b1.n_groups)), (0, 1, 3))
    got = kops.vqc_fidelity_shiftgroups_multibank(
        spec, (b1.theta, b2.theta), (b1.data, b2.data), False, gs)
    want = tuple(
        kops.vqc_fidelity_shiftgroups(spec, b.theta, b.data, False, g)
        for b, g in zip((b1, b2), gs))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)


def test_plan_depth_tiles_never_split_replay_spans():
    """A multi-use parameter's [first, last] span is atomic under tiling:
    its checkpoint is always derivable inside its anchor's tile."""
    spec = circuits.build_tied_quclassi_circuit(7, 3)
    plan = K.build_shift_plan(spec)
    anchors = sorted(ps[-1] for ps in plan.theta_positions)
    budget = K.checkpoint_vmem_bytes(plan, 3, 128)
    tiles = K.plan_depth_tiles(plan, anchors, 128, budget)
    assert tiles is not None
    assert tiles[0][0] == 0 and tiles[-1][1] == len(plan.train_ops)
    for (a, b), (c, d) in zip(tiles, tiles[1:]):
        assert b == c and a < b
    for ps in plan.theta_positions:
        tile = next((lo, hi) for lo, hi in tiles if lo <= ps[-1] < hi)
        assert tile[0] <= ps[0], (ps, tile)   # first stays in anchor's tile


def test_cost_crossover_selects_materialize():
    """Plan selection is a cost comparison, not plan existence: one variant
    of a whole-circuit replay span is cheaper materialized, and the ops
    layer routes it there with unchanged numerics."""
    spec = _deep_reuse_spec(r=20)
    assert K.build_shift_plan(spec) is not None
    # full bank: implicit still wins (materializing pays data+tail per group)
    assert K.use_shift_plan(spec)
    full_cost = K.shift_cost_info(spec)
    assert full_cost["gate_apps_implicit"] < full_cost["gate_apps_materialized"]
    assert full_cost["replay_depth_max"] == 20
    # single deep variant: replay cost crosses over
    sub = K.shift_cost_info(spec, False, (1,))
    assert sub["gate_apps_implicit"] > sub["gate_apps_materialized"]
    assert not K.use_shift_plan(spec, False, (1,))
    info = K.shift_execution_info(spec, 8, groups=(1,))
    assert info["mode"] == "materialize"
    # the ops layer takes the materialized path and stays correct
    theta = jnp.asarray([[0.4], [1.1]], jnp.float32)
    data = jnp.asarray([[0.2], [0.8]], jnp.float32)
    bank = shift_rule.build_shift_bank(theta, data)
    got = kops.vqc_fidelity_shiftgroups(spec, bank.theta, bank.data, False,
                                        (1,))
    mat = bank.materialize()
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data).reshape(
        bank.n_groups, 2)[1:2]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_cost_model_ranks_multiuse_banks():
    """Regression (CostModel mis-ranking): a 2-reuse bank is charged the
    analytic suffix-replay cost, NOT the full materialized cost — so the
    coalescer/placement rank it between the single-use bank and the
    materialized fallback."""
    from repro.api.backend import CostModel
    cm = CostModel(shiftbank=True)
    qc, nl = 7, 3
    single = circuits.build_quclassi_circuit(qc, nl)
    tied = circuits.build_tied_quclassi_circuit(qc, nl)
    theta = jnp.zeros((single.n_theta,), jnp.float32)
    data = jnp.zeros((64, single.n_data), jnp.float32)
    bank_s = shift_rule.build_shift_bank(theta, data)
    bank_t = shift_rule.build_shift_bank(theta, data)
    cost_single = cm.bank_cost_units(single, bank_s)
    cost_tied = cm.bank_cost_units(tied, bank_t)
    mat_tied = cm.bank_cost_units(tied, bank_t.materialize())
    # pinned ordering: single-use < 2-reuse replay << materialized
    assert cost_single < cost_tied < mat_tied
    assert cost_tied <= mat_tied / 3      # the >=3x acceptance headroom
    # the charge IS the analytic replay cost
    want = K.shift_cost_info(tied)["gate_apps_implicit"] * 128
    assert cost_tied == float(want)
    # deep-reuse full-span banks still never exceed the materialized charge
    # (at lane-saturating batch sizes where padding doesn't skew the units)
    deep = _deep_reuse_spec(r=20)
    bank_d = shift_rule.build_shift_bank(
        jnp.zeros((128, 1), jnp.float32), jnp.zeros((128, 1), jnp.float32))
    assert cm.bank_cost_units(deep, bank_d) < cm.bank_cost_units(
        deep, bank_d.materialize())


def test_shift_bank_stats_multiuse_ratio():
    """The 7q/3l 2-reuse ansatz clears the >=3x gate-apps acceptance bar."""
    spec = circuits.build_tied_quclassi_circuit(7, 3)
    stats = K.shift_bank_stats(spec, 64)
    assert stats["gate_apps_ratio"] >= 3.0, stats
    # and the classic single-use ratio is unchanged by the generalization
    classic = K.shift_bank_stats(circuits.build_quclassi_circuit(7, 3), 64)
    assert classic["gate_apps_ratio"] >= 5.0, classic
