"""Shift-structured circuit-bank execution: implicit ``ShiftBank``s, the
prefix-reuse kernel, group-scheduled data-plane executors, and the serving
gateway's per-(param, shift)-group path.

Correctness contract: everything here must agree with the MATERIALIZED bank
(``build_bank`` + the standard fused kernel / dense-sim oracle) — scheduling
and the shift-structured execution strategy never change the math.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import circuits, shift_rule
from repro.core.sim import CircuitSpec, Op
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels import vqc_statevector as K


def _setup(qc, nl, b=3, seed=0):
    spec = circuits.build_quclassi_circuit(qc, nl)
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, (spec.n_theta,), jnp.float32,
                               minval=0.0, maxval=np.pi)
    data = jax.random.uniform(jax.random.fold_in(key, 1), (b, spec.n_data),
                              jnp.float32, minval=0.0, maxval=np.pi)
    return spec, theta, data


# ------------------------------------------------------------ ShiftBank
@pytest.mark.parametrize("qc,nl", [(5, 1), (5, 3), (7, 1), (7, 3)])
@pytest.mark.parametrize("four_term", [False, True])
@pytest.mark.parametrize("seed", [0, 7])
def test_materialize_reproduces_build_bank_exactly(qc, nl, four_term, seed):
    """The escape hatch is BIT-identical to build_bank, not just close."""
    spec, theta, data = _setup(qc, nl, b=4, seed=seed)
    implicit = shift_rule.build_shift_bank(theta, data, four_term=four_term)
    explicit = shift_rule.build_bank(theta, data, four_term=four_term)
    mat = implicit.materialize()
    assert np.array_equal(np.asarray(mat.theta), np.asarray(explicit.theta))
    assert np.array_equal(np.asarray(mat.data), np.asarray(explicit.data))
    assert (mat.n_samples, mat.n_params, mat.four_term) == \
        (explicit.n_samples, explicit.n_params, explicit.four_term)


def test_shiftbank_bookkeeping_matches_circuitbank():
    spec, theta, data = _setup(5, 2, b=3)
    bank = shift_rule.build_shift_bank(theta, data)
    assert bank.n_groups == 1 + 2 * spec.n_theta
    assert bank.n_circuits == bank.n_groups * 3
    f = jnp.arange(bank.n_circuits, dtype=jnp.float32)
    for got, want in zip(bank.split_results(f),
                         bank.materialize().split_results(f)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    descs = bank.group_descriptors()
    assert descs[0] == (-1, 0.0)
    assert len(descs) == bank.n_groups
    assert descs[1][0] == 0 and descs[1][1] == pytest.approx(np.pi / 2)
    assert descs[1 + spec.n_theta][1] == pytest.approx(-np.pi / 2)


def test_per_sample_theta_shiftbank():
    """ShiftBank generalizes build_bank: per-sample base thetas are allowed."""
    spec, _, data = _setup(5, 1, b=4)
    theta = jax.random.uniform(jax.random.PRNGKey(3), (4, spec.n_theta),
                               jnp.float32, minval=0.0, maxval=np.pi)
    bank = shift_rule.build_shift_bank(theta, data)
    mat = bank.materialize()
    j, b = 2, 1
    row = np.asarray(mat.theta[4 + j * 4 + b])
    expect = np.asarray(theta[b]).copy()
    expect[j] += np.pi / 2
    np.testing.assert_allclose(row, expect, atol=1e-6)
    got = kops.vqc_fidelity_shiftbank(spec, bank.theta, bank.data)
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------- prefix-reuse kernel
@pytest.mark.parametrize("qc", [5, 7])
@pytest.mark.parametrize("nl", [1, 3])
@pytest.mark.parametrize("four_term", [False, True])
def test_prefix_reuse_matches_ref(qc, nl, four_term):
    spec, theta, data = _setup(qc, nl, b=3, seed=qc * 10 + nl)
    bank = shift_rule.build_shift_bank(theta, data, four_term=four_term)
    mat = bank.materialize()
    got = kops.vqc_fidelity_shiftbank(spec, bank.theta, bank.data, four_term)
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data)
    assert got.shape == (bank.n_circuits,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_group_subset_matches_full():
    spec, theta, data = _setup(5, 3, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    full = np.asarray(kops.vqc_fidelity_shiftgroups(spec, bank.theta,
                                                    bank.data))
    groups = (0, 2, 5, bank.n_groups - 1)
    sub = np.asarray(kops.vqc_fidelity_shiftgroups(spec, bank.theta,
                                                   bank.data, False, groups))
    np.testing.assert_allclose(sub, full[list(groups)], atol=1e-6)


def test_shift_plan_structure():
    spec = circuits.build_quclassi_circuit(7, 3)
    plan = K.build_shift_plan(spec)
    assert plan is not None
    m = (7 - 1) // 2
    assert plan.m == m
    assert len(plan.data_ops) == spec.n_data
    assert len(plan.train_ops) == spec.n_theta
    # every parameter has a unique dependent gate, in circuit order
    assert plan.theta_pos == tuple(range(spec.n_theta))


def test_shift_plan_rejects_unstructured_circuits():
    # no SWAP-test tail -> no product structure to exploit
    spec = CircuitSpec(n_qubits=2, ops=(Op("ry", (0,), ("theta", 0)),
                                        Op("ry", (1,), ("data", 0))),
                       n_theta=1, n_data=1)
    assert K.build_shift_plan(spec) is None
    # fallback path still produces correct bank fidelities
    theta = jnp.asarray([[0.3], [0.9]], jnp.float32)
    data = jnp.asarray([[0.1], [0.4]], jnp.float32)
    bank = shift_rule.build_shift_bank(theta, data)
    got = kops.vqc_fidelity_shiftbank(spec, bank.theta, bank.data)
    mat = bank.materialize()
    want = ref.vqc_fidelity_ref(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_shift_bank_stats_acceptance_ratios():
    """The paper's 7q/3l config: >=5x fewer gate applications, >=10x fewer
    angle bytes than the materialized bank (ISSUE acceptance)."""
    spec = circuits.build_quclassi_circuit(7, 3)
    stats = K.shift_bank_stats(spec, n_samples=64)
    assert stats["gate_apps_ratio"] >= 5.0
    assert stats["angle_bytes_ratio"] >= 10.0


# -------------------------------------------- descending two-qubit pairs
def test_rot2_descending_symmetric_pairs():
    """RYY/RZZ are symmetric under qubit exchange; the kernel now accepts
    descending pairs instead of raising (satellite fix)."""
    ops_desc = (Op("ry", (0,), ("data", 0)), Op("ryy", (1, 0), ("theta", 0)),
                Op("rzz", (2, 1), ("theta", 1)))
    ops_asc = (Op("ry", (0,), ("data", 0)), Op("ryy", (0, 1), ("theta", 0)),
               Op("rzz", (1, 2), ("theta", 1)))
    sd = CircuitSpec(n_qubits=3, ops=ops_desc, n_theta=2, n_data=1)
    sa = CircuitSpec(n_qubits=3, ops=ops_asc, n_theta=2, n_data=1)
    theta = jnp.asarray([[0.7, 1.1], [0.2, 2.0]], jnp.float32)
    data = jnp.asarray([[0.5], [1.3]], jnp.float32)
    re_d, im_d = kops.vqc_state(sd, theta, data)
    re_a, im_a = kops.vqc_state(sa, theta, data)
    np.testing.assert_allclose(np.asarray(re_d), np.asarray(re_a), atol=1e-6)
    np.testing.assert_allclose(np.asarray(im_d), np.asarray(im_a), atol=1e-6)
    # and against the dense-sim oracle, which permutes axes generically
    re_r, im_r = ref.vqc_state_ref(sd, theta, data)
    np.testing.assert_allclose(np.asarray(re_d), np.asarray(re_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(im_d), np.asarray(im_r), atol=1e-5)


def test_rot2_descending_controlled_still_raises():
    ops_bad = (Op("cry", (1, 0), ("theta", 0)),)
    spec = CircuitSpec(n_qubits=2, ops=ops_bad, n_theta=1, n_data=0)
    theta = jnp.asarray([[0.7]], jnp.float32)
    data = jnp.zeros((1, 0), jnp.float32)
    with pytest.raises(NotImplementedError):
        kops.vqc_state(spec, theta, data)


# -------------------------------------------------- gradient equivalence
@pytest.mark.parametrize("qc,nl,exact", [(5, 1, False), (5, 3, True),
                                         (7, 2, False)])
def test_parameter_shift_grad_implicit_vs_materialized(qc, nl, exact):
    spec, theta, data = _setup(qc, nl, b=3, seed=nl)
    labels = jnp.asarray([0.0, 1.0, 1.0])
    l_mat, g_mat, f_mat = shift_rule.parameter_shift_grad(
        spec, theta, data, labels, exact_controlled=exact)
    l_imp, g_imp, f_imp = shift_rule.parameter_shift_grad(
        spec, theta, data, labels, executor=kops.shiftbank_executor(spec),
        exact_controlled=exact)
    np.testing.assert_allclose(float(l_imp), float(l_mat), atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_imp), np.asarray(g_mat), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_imp), np.asarray(f_mat), atol=1e-5)


def test_implicit_flag_with_shift_unaware_executor():
    """implicit=True + a plain (theta, data) executor goes through
    materialize() — the compatibility escape hatch."""
    spec, theta, data = _setup(5, 1, b=2)
    labels = jnp.asarray([1.0, 0.0])
    seen = {}

    def executor(t, d):
        seen["shape"] = (t.shape, d.shape)
        from repro.core import fidelity as fid
        return fid.fidelity_batch(spec, t, d)

    l1, g1, _ = shift_rule.parameter_shift_grad(spec, theta, data, labels,
                                                executor=executor,
                                                implicit=True)
    c = 2 * (2 * spec.n_theta + 1)
    assert seen["shape"] == ((c, spec.n_theta), (c, spec.n_data))
    l0, g0, _ = shift_rule.parameter_shift_grad(spec, theta, data, labels)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0), atol=1e-6)


# ------------------------------------------------- data-plane executors
def test_worker_batched_group_assignment():
    from repro.comanager import dataplane
    spec, theta, data = _setup(5, 2, b=5)
    bank = shift_rule.build_shift_bank(theta, data)
    assignment = dataplane.round_robin_assignment(bank.n_groups, 3)
    run = dataplane.worker_batched_executor(spec, assignment, 3)
    assert run.accepts_shiftbank
    got = run(bank)
    mat = bank.materialize()
    want = kops.vqc_fidelity(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_worker_batched_row_assignment_accepts_implicit_bank():
    """Legacy per-row assignments still work on implicit banks (materialize
    fallback preserves exact per-worker row placement)."""
    from repro.comanager import dataplane
    spec, theta, data = _setup(5, 1, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    assignment = dataplane.round_robin_assignment(bank.n_circuits, 2)
    run = dataplane.worker_batched_executor(spec, assignment, 2)
    mat = bank.materialize()
    np.testing.assert_allclose(np.asarray(run(bank)),
                               np.asarray(run(mat.theta, mat.data)),
                               atol=1e-6)


def test_worker_batched_bad_assignment_length():
    from repro.comanager import dataplane
    spec, theta, data = _setup(5, 1, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    run = dataplane.worker_batched_executor(spec, [0, 1], 2)
    with pytest.raises(ValueError, match="groups"):
        run(bank)


def test_sharded_executor_accepts_implicit_bank():
    from repro.comanager import dataplane
    from repro.launch.mesh import make_host_mesh
    spec, theta, data = _setup(5, 2, b=5)    # odd B exercises sample padding
    bank = shift_rule.build_shift_bank(theta, data)
    run = dataplane.sharded_executor(spec, make_host_mesh())
    got = run(bank)
    assert got.shape == (bank.n_circuits,)
    mat = bank.materialize()
    want = kops.vqc_fidelity(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------------ serving gateway
def test_gateway_shift_executor_matches_materialized():
    from repro.serve import GatewayRuntime, ShiftGroupKey
    spec, theta, data = _setup(5, 2, b=4)
    bank = shift_rule.build_shift_bank(theta, data)
    rt = GatewayRuntime()
    run = rt.shift_executor(spec, "tenant-a")
    assert run.accepts_shiftbank
    got = run(bank)
    mat = bank.materialize()
    want = kops.vqc_fidelity(spec, mat.theta, mat.data)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # groups were dispatched as shift-group batches, not per-row circuits
    assert rt.dispatcher.batch_log, "no batches executed"
    total_members = sum(n for (_, n, _) in rt.dispatcher.batch_log)
    assert total_members == bank.n_groups
    # lane-fill telemetry counts the kernel lanes the groups occupy
    # (n_groups * B sample lanes), not the group-subtask member count,
    # and pays per-group row padding (each group pads its B samples
    # independently in the kernel launch)
    assert rt.telemetry.batched_circuits == bank.n_groups * bank.n_samples
    import math
    per_group = math.ceil(bank.n_samples / rt.gateway.coalescer.lanes) * \
        rt.gateway.coalescer.lanes
    assert rt.telemetry.padded_lanes == bank.n_groups * per_group


def test_shift_executors_accept_materialized_banks():
    """Shift-aware executors still take plain (theta, data) calls, so
    bank_mode='materialized' composes with them instead of crashing."""
    from repro.serve import GatewayRuntime
    spec, theta, data = _setup(5, 1, b=3)
    bank = shift_rule.build_shift_bank(theta, data)
    mat = bank.materialize()
    want = np.asarray(kops.vqc_fidelity(spec, mat.theta, mat.data))
    np.testing.assert_allclose(
        np.asarray(kops.shiftbank_executor(spec)(mat.theta, mat.data)),
        want, atol=1e-6)
    rt = GatewayRuntime()
    run = rt.shift_executor(spec, "tenant-a")
    np.testing.assert_allclose(np.asarray(run(mat.theta, mat.data)), want,
                               atol=1e-5)
    # and run_bank routes a materialized CircuitBank through the same path
    np.testing.assert_allclose(
        np.asarray(shift_rule.run_bank(run, mat)), want, atol=1e-5)


def test_gateway_shift_groups_coalesce_within_bank_only():
    """Different banks (different base angles) never share a kernel launch."""
    from repro.serve import ShiftGroupKey
    spec, theta, data = _setup(5, 1, b=2)
    k1 = ShiftGroupKey(spec, 1)
    k2 = ShiftGroupKey(spec, 2)
    assert k1 != k2 and hash(k1) != hash(k2)
    assert k1 == ShiftGroupKey(spec, 1)


def test_grad_shift_through_gateway_shift_executor():
    from repro.core import quclassi
    from repro.core.quclassi import QuClassiConfig
    from repro.data import mnist
    from repro.serve import GatewayRuntime
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(3, 9, n_per_class=4, seed=0)
    x, y = jnp.asarray(x[:3]), jnp.asarray(y[:3])
    params = quclassi.init_params(cfg, jax.random.PRNGKey(0))
    rt = GatewayRuntime()
    ex = rt.shift_executor(cfg.spec, "trainer")
    l_gw, g_gw, _ = quclassi.grad_shift(cfg, params, x, y, executor=ex)
    l_ref, g_ref, _ = quclassi.grad_shift(cfg, params, x, y)
    np.testing.assert_allclose(float(l_gw), float(l_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_gw["theta"]),
                               np.asarray(g_ref["theta"]), atol=1e-5)


def test_gateway_shift_keys_do_not_leak_coalescer_buffers():
    """Every bank submission mints a fresh ShiftGroupKey; emptied buffers
    must be retired or a long training run grows the coalescer forever."""
    from repro.serve import GatewayRuntime
    spec, theta, data = _setup(5, 1, b=2)
    rt = GatewayRuntime()
    run = rt.shift_executor(spec, "tenant-a")
    for i in range(5):
        run(shift_rule.build_shift_bank(theta + 0.01 * i, data))
    assert len(rt.gateway.coalescer._buffers) == 0


def test_dispatcher_shift_kernel_injectable():
    """GatewayRuntime(shift_kernel=...) substitutes the shift-group runner,
    mirroring the documented KernelFn substitution point."""
    from repro.serve import GatewayRuntime
    spec, theta, data = _setup(5, 1, b=3)
    bank = shift_rule.build_shift_bank(theta, data)
    calls = []

    def stub(s, t, d, four_term, groups):
        calls.append(groups)
        return kops.vqc_fidelity_shiftgroups(s, t, d, four_term, groups)

    rt = GatewayRuntime(shift_kernel=stub)
    run = rt.shift_executor(spec, "tenant-a")
    got = run(bank)
    assert calls and sorted(g for gs in calls for g in gs) == \
        list(range(bank.n_groups))
    mat = bank.materialize()
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(kops.vqc_fidelity(spec, mat.theta,
                                                      mat.data)), atol=1e-5)


def test_trainer_bank_mode_validation():
    from repro.core import trainer
    from repro.core.quclassi import QuClassiConfig
    with pytest.raises(ValueError, match="bank_mode"):
        trainer.train(QuClassiConfig(), (np.zeros((2, 8, 8)), np.zeros(2)),
                      (np.zeros((2, 8, 8)), np.zeros(2)),
                      epochs=0, bank_mode="bogus")
