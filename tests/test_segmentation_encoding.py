"""Task Segmentation (paper §III-A) + data-encoding tests, incl. properties."""
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import encoding, segmentation
from repro.core.segmentation import SegmentationConfig


def test_paper_settings_patch_count():
    # paper: 8x8 image, w=4, s=2 -> 3x3 patches
    cfg = SegmentationConfig(filter_width=4, stride=2, n_filters=4)
    assert segmentation.n_patches(8, 8, cfg) == (3, 3)
    assert segmentation.subtasks_per_image(8, 8, cfg) == 36


def test_segment_contents():
    cfg = SegmentationConfig(filter_width=2, stride=2, n_filters=1)
    img = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4)
    out = segmentation.segment(img, cfg)
    assert out.shape == (1, 4, 4)
    np.testing.assert_allclose(np.asarray(out[0, 0]), [0, 1, 4, 5])
    np.testing.assert_allclose(np.asarray(out[0, 3]), [10, 11, 14, 15])


def test_segment_padding():
    cfg = SegmentationConfig(filter_width=3, stride=2, n_filters=1)
    img = jnp.ones((1, 4, 4), jnp.float32)
    ph, pw = segmentation.n_patches(4, 4, cfg)
    out = segmentation.segment(img, cfg)
    assert out.shape == (1, ph * pw, 9)
    # last patch covers rows/cols 2..4 -> one padded row+col of zeros
    last = np.asarray(out[0, -1]).reshape(3, 3)
    np.testing.assert_allclose(last[:2, :2], 1.0)
    np.testing.assert_allclose(last[2, :], 0.0)
    np.testing.assert_allclose(last[:, 2], 0.0)


@given(h=st.integers(4, 16), w=st.integers(4, 16),
       fw=st.integers(2, 5), s=st.integers(1, 4))
def test_coverage_property(h, w, fw, s):
    """Every source pixel is covered by at least one patch (requires
    stride <= filter width, as in the paper's s=2 < w=4 setting)."""
    from hypothesis import assume
    assume(s <= fw)
    cfg = SegmentationConfig(filter_width=fw, stride=s, n_filters=1)
    cov = segmentation.reassemble_coverage(h, w, cfg)
    assert cov.shape == (h, w)
    assert (cov >= 1).all()


@given(h=st.integers(4, 12), w=st.integers(4, 12),
       fw=st.integers(2, 4), s=st.integers(1, 3), b=st.integers(1, 3))
def test_segment_shape_property(h, w, fw, s, b):
    cfg = SegmentationConfig(filter_width=fw, stride=s, n_filters=1)
    ph, pw = segmentation.n_patches(h, w, cfg)
    imgs = jnp.ones((b, h, w), jnp.float32)
    out = segmentation.segment(imgs, cfg)
    assert out.shape == (b, ph * pw, fw * fw)


def test_segment_is_jittable():
    import jax
    cfg = SegmentationConfig()
    f = jax.jit(lambda x: segmentation.segment(x, cfg))
    out = f(jnp.ones((2, 8, 8)))
    assert out.shape[0] == 2


# ---------------------------------------------------------------- encoding
def test_rotation_angles_exact_size():
    patch = jnp.array([0.0, 0.5, 1.0, 0.25])
    a = encoding.rotation_angles(patch, 4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(patch) * np.pi, atol=1e-6)


def test_rotation_angles_pool_and_tile():
    patch = jnp.arange(8, dtype=jnp.float32) / 8.0
    pooled = encoding.rotation_angles(patch, 4)
    assert pooled.shape == (4,)
    np.testing.assert_allclose(np.asarray(pooled)[0],
                               np.pi * (0 + 1 / 8) / 2, atol=1e-6)
    tiled = encoding.rotation_angles(jnp.array([0.5, 1.0]), 5)
    assert tiled.shape == (5,)
    np.testing.assert_allclose(np.asarray(tiled),
                               np.pi * np.array([0.5, 1, 0.5, 1, 0.5]), atol=1e-6)


def test_rotation_angle_roundtrip():
    patch = jnp.array([0.1, 0.9, 0.4, 0.7])
    a = encoding.rotation_angles(patch, 4)
    np.testing.assert_allclose(np.asarray(encoding.angles_to_unit_interval(a)),
                               np.asarray(patch), atol=1e-6)


@given(vals=st.lists(st.floats(-5, 5, allow_nan=False), min_size=4, max_size=4))
def test_amplitude_encoding_normalized(vals):
    re, im = encoding.amplitude_encoding(jnp.asarray(vals, jnp.float32))
    norm = float(jnp.sum(re * re + im * im))
    assert abs(norm - 1.0) < 1e-5


def test_amplitude_encoding_zero_fallback():
    re, im = encoding.amplitude_encoding(jnp.zeros(8))
    np.testing.assert_allclose(np.asarray(re), np.eye(8)[0], atol=1e-7)


def test_amplitude_encoding_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        encoding.amplitude_encoding(jnp.ones(6))
