"""Fig 6: multi-client, multi-tenant system — 4 concurrent clients
(5Q/1L, 5Q/2L, 7Q/1L, 7Q/2L) against 4 heterogeneous workers
(5/10/15/20 qubits), multi-tenant vs single-tenant semantics.

Headline paper claims reproduced here:
  * 68.7% runtime reduction for 5Q/1L under multi-tenancy,
  * 8.2% for 7Q/2L (the 5-qubit worker is useless to 7-qubit circuits),
  * up to 3.9x circuits/sec (5Q/1L: 1.4 -> 5.5).
"""
from __future__ import annotations

from benchmarks import paper_data as PD
from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation
from repro.comanager.worker import WorkerConfig

CLIENTS = [("5q1l", 5, 1), ("5q2l", 5, 2), ("7q1l", 7, 1), ("7q2l", 7, 2)]


def make_jobs(scale: float = 1.0):
    """Fig-6 jobs are WORKER-bound: the e2-medium quantum simulators carry
    the per-circuit cost (1/GCP-rate), the client side only dispatches."""
    from repro.comanager.worker import PAPER_RATES_GCP
    jobs = []
    for cid, qc, nl in CLIENTS:
        n = max(8, int(PD.N_CIRCUITS[(qc, nl)] * scale))
        jobs.append(
            tenancy.JobSpec(
                cid, qc, nl, n, service_override=1.0 / PAPER_RATES_GCP[(qc, nl)]
            )
        )
    return jobs


#: co-residency slowdown 0.5: the paper's workers are e2-medium VMs with "1
#: shared core", so two co-resident circuit simulations each run ~1.5x slower
#: (half-serialized).  Calibrated once against Fig 6's 5q1l endpoint; the
#: other seven numbers below are then predictions.
CONTENTION = 0.5


def workers():
    return [
        WorkerConfig(f"w{i+1}", q, contention=CONTENTION)
        for i, q in enumerate((5, 10, 15, 20))
    ]


def run(multi_tenant: bool, scale: float = 0.25):
    """Single-tenant baseline = "single_circuit": one circuit occupies the
    whole machine at a time ("one user occupies the entire machine while
    others wait in a queue") — multi-tenancy's win is co-residency."""
    sim = SystemSimulation(
        workers(),
        make_jobs(scale),
        tenancy="multi" if multi_tenant else "single_circuit",
        classical_overhead=0.01,
        fair_queue=True,
        assign_latency=PD.ASSIGN_LATENCY,
    )
    return sim.run()


def rows(scale: float = 0.25):
    multi = run(True, scale)
    single = run(False, scale)
    out = []
    for cid, qc, nl in CLIENTS:
        jm, js = multi.jobs[cid], single.jobs[cid]
        red = 1 - jm.makespan / js.makespan
        gain = jm.circuits_per_second / js.circuits_per_second
        row = {
            "figure": "fig6",
            "client": cid,
            "multi_runtime_s": round(jm.makespan, 1),
            "single_runtime_s": round(js.makespan, 1),
            "runtime_reduction": f"{red:.1%}",
            "cps_multi": round(jm.circuits_per_second, 2),
            "cps_single": round(js.circuits_per_second, 2),
            "cps_gain": f"{gain:.2f}x",
            "paper_reduction": (
                f"{PD.FIG6_REDUCTION[cid]:.1%}" if cid in PD.FIG6_REDUCTION else ""
            ),
        }
        out.append(row)
    return out


def main():
    all_rows = rows()
    keys = list(all_rows[0])
    print(",".join(keys))
    for r in all_rows:
        print(",".join(str(r[k]) for k in keys))
    # claim checks
    r51 = next(r for r in all_rows if r["client"] == "5q1l")
    r72 = next(r for r in all_rows if r["client"] == "7q2l")
    print(
        f"# multi-tenancy helps 5q1l ({r51['runtime_reduction']}) far more "
        f"than 7q2l ({r72['runtime_reduction']}) — paper: 68.7% vs 8.2%"
    )
    return all_rows


if __name__ == "__main__":
    main()
