"""§IV-B accuracy: distributed vs non-distributed QuClassi on the paper's
binary tasks (3/9, 3/8, 3/6, 1/5).

Paper claim: distributed accuracies 97.5 / 96.2 / 98.1 / 98.6 %, within 2%
of the non-distributed design.  In our system the distributed executor is
bit-equivalent, so we demonstrate (a) the trained accuracy per task and
(b) |distributed - local| gradient agreement == 0 (stronger than the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.comanager import dataplane
from repro.core import quclassi
from repro.core.quclassi import QuClassiConfig
from repro.core.trainer import train
from repro.data import mnist

PAPER_ACC = {(3, 9): 0.975, (3, 8): 0.962, (3, 6): 0.981, (1, 5): 0.986}


def run_task(
    a: int, b: int, *, epochs: int = 40, n_per_class: int = 60, seed: int = 0
):
    """Paper settings: epsilon=40 epochs; 2-layer (single+dual) circuits give
    the best accuracy on our synthetic MNIST stand-in."""
    cfg = QuClassiConfig(qc=5, n_layers=2)
    x, y = mnist.make_pair_dataset(a, b, n_per_class=n_per_class, seed=seed)
    (xtr, ytr), (xte, yte) = mnist.train_test_split(x, y)
    rep = train(
        cfg,
        (xtr, ytr),
        (xte, yte),
        epochs=epochs,
        batch_size=16,
        lr=0.05,
        optimizer="adam",
        grad_mode="autodiff",
        seed=seed,
    )
    return rep


def gradient_equivalence(a: int, b: int) -> float:
    """max |distributed - local| theta gradient over one step."""
    cfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(a, b, n_per_class=8, seed=0)
    xb, yb = jnp.asarray(x[:8]), jnp.asarray(y[:8])
    p = quclassi.init_params(cfg, jax.random.PRNGKey(0))
    n_bank = (2 * cfg.n_theta + 1) * 8 * cfg.n_patches
    ex = dataplane.worker_batched_executor(
        cfg.spec, dataplane.round_robin_assignment(n_bank, 4), 4
    )
    _, g1, _ = quclassi.grad_shift(cfg, p, xb, yb, executor=ex)
    _, g2, _ = quclassi.grad_shift(cfg, p, xb, yb)
    return float(jnp.abs(g1["theta"] - g2["theta"]).max())


def rows(epochs: int = 40):
    out = []
    for (a, b), paper in PAPER_ACC.items():
        rep = run_task(a, b, epochs=epochs)
        best = max(e.test_accuracy for e in rep.epochs)
        out.append(
            {
                "task": f"{a}/{b}",
                "test_accuracy": round(rep.final_test_accuracy, 3),
                "best_accuracy": round(best, 3),
                "paper_accuracy": paper,
                "dist_vs_local_grad_gap": f"{gradient_equivalence(a, b):.1e}",
            }
        )
    return out


def main(epochs: int = 40):
    all_rows = rows(epochs)
    keys = list(all_rows[0])
    print(",".join(keys))
    for r in all_rows:
        print(",".join(str(r[k]) for k in keys))
    print(
        "# distributed == local gradients (gap ~1e-7): distribution "
        "cannot change accuracy — stronger than the paper's <2% claim"
    )
    return all_rows


if __name__ == "__main__":
    main()
