"""Serving-gateway throughput: cross-tenant circuit-bank coalescing vs the
per-circuit dispatch path, on the Fig-6-shaped multi-tenant workload.

Three modes:

* ``fig6``    — 4 concurrent clients (5Q/1L, 5Q/2L, 7Q/1L, 7Q/2L) against 4
  heterogeneous workers (5/10/15/20 qubits), on the virtual clock.  The
  baseline is the paper's per-circuit co-managed dispatch; the gateway path
  coalesces compatible circuits across tenants into lane-aligned mega-batches
  (one Algorithm-2 task each, fused-kernel cost model).

* ``poisson`` — open-loop serving stand-in: each client's circuits arrive as
  a Poisson stream rather than an epoch burst, so the coalescer has to trade
  batch fill against the flush deadline.  Reports per-tenant p50/p99 latency
  and the lane-fill rate.

* ``kernel``  — real-execution sanity check (no virtual clock): wall-clock
  circuits/sec of one coalesced Pallas launch vs per-circuit kernel launches.

Run:  PYTHONPATH=src:. python benchmarks/gateway_throughput.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import paper_data as PD
from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation
from repro.comanager.worker import PAPER_RATES_GCP, WorkerConfig

CLIENTS = [("5q1l", 5, 1), ("5q2l", 5, 2), ("7q1l", 7, 1), ("7q2l", 7, 2)]
CONTENTION = 0.5   # same co-residency slowdown as benchmarks/multitenant.py


def workers():
    return [WorkerConfig(f"w{i+1}", q, contention=CONTENTION)
            for i, q in enumerate((5, 10, 15, 20))]


def make_jobs(scale: float = 0.25):
    jobs = []
    for cid, qc, nl in CLIENTS:
        n = max(8, int(PD.N_CIRCUITS[(qc, nl)] * scale))
        jobs.append(tenancy.JobSpec(cid, qc, nl, n,
                                    service_override=1.0 / PAPER_RATES_GCP[(qc, nl)]))
    return jobs


# ------------------------------------------------------------------- fig6
def fig6(scale: float = 0.25):
    """Coalesced gateway vs uncoalesced per-circuit dispatch, closed world."""
    common = dict(classical_overhead=0.01, assign_latency=PD.ASSIGN_LATENCY)
    base = SystemSimulation(workers(), make_jobs(scale), fair_queue=True,
                            **common).run()
    gw = SystemSimulation(workers(), make_jobs(scale), gateway=True,
                          gateway_deadline=1.0, **common).run()
    rows = []
    for cid, qc, nl in CLIENTS:
        jb, jg = base.jobs[cid], gw.jobs[cid]
        rows.append({
            "client": cid,
            "cps_uncoalesced": round(jb.circuits_per_second, 2),
            "cps_gateway": round(jg.circuits_per_second, 2),
            "gain": f"{jg.circuits_per_second / jb.circuits_per_second:.1f}x",
        })
    return base, gw, rows


# ---------------------------------------------------------------- poisson
#: serving tenants arrive in structural families — two tenants per circuit
#: shape — so the coalescer's cross-tenant packing actually has peers to
#: pack with (a tenant alone at 60 c/s can only ~half-fill a 128-lane batch
#: within the deadline; two tenants sharing a structure fill it).
POISSON_CLIENTS = [("alice-5q", 5, 1), ("bob-5q", 5, 1),
                   ("carol-7q", 7, 1), ("dave-7q", 7, 1)]


def poisson(rate_per_client: float = 60.0, n_per_client: int = 300,
            deadline: float = 1.0, seed: int = 0):
    """Open-loop arrivals: per-circuit Poisson streams instead of one burst."""
    rng = np.random.default_rng(seed)
    jobs, arrivals = [], {}
    for cid, qc, nl in POISSON_CLIENTS:
        jobs.append(tenancy.JobSpec(cid, qc, nl, n_per_client,
                                    service_override=1.0 / PAPER_RATES_GCP[(qc, nl)]))
        arrivals[cid] = np.cumsum(
            rng.exponential(1.0 / rate_per_client, n_per_client)).tolist()
    sim = SystemSimulation(workers(), jobs, gateway=True,
                           gateway_deadline=deadline, arrivals=arrivals,
                           classical_overhead=0.01,
                           assign_latency=PD.ASSIGN_LATENCY)
    return sim.run()


# ----------------------------------------------------------------- kernel
def kernel(n: int = 128, qc: int = 5, n_layers: int = 1, seed: int = 0):
    """Real data plane: one coalesced launch vs n per-circuit launches."""
    import jax.numpy as jnp
    from repro.core import circuits
    from repro.kernels import ops as kops

    spec = circuits.build_quclassi_circuit(qc, n_layers)
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.uniform(0, np.pi, (n, spec.n_theta)), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (n, spec.n_data)), jnp.float32)

    kops.vqc_fidelity(spec, theta, data).block_until_ready()   # warm both jits
    kops.vqc_fidelity(spec, theta[:1], data[:1]).block_until_ready()

    t0 = time.perf_counter()
    f_big = kops.vqc_fidelity(spec, theta, data).block_until_ready()
    t_coalesced = time.perf_counter() - t0

    t0 = time.perf_counter()
    singles = [kops.vqc_fidelity(spec, theta[i:i + 1], data[i:i + 1])
               for i in range(n)]
    f_per = np.concatenate([np.asarray(s) for s in singles])
    t_single = time.perf_counter() - t0

    np.testing.assert_allclose(np.asarray(f_big), f_per, atol=1e-6)
    return {
        "n_circuits": n,
        "coalesced_cps": round(n / t_coalesced, 1),
        "per_circuit_cps": round(n / t_single, 1),
        "speedup": f"{t_single / t_coalesced:.1f}x",
    }


def main(run_kernel: bool = True, scale: float = 0.25):
    print("## fig6-shaped workload: 4 clients x 4 workers (virtual clock)")
    base, gw, rows = fig6(scale)
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    gain = gw.circuits_per_second / base.circuits_per_second
    print(f"# system: {base.circuits_per_second:.1f} -> "
          f"{gw.circuits_per_second:.1f} circuits/sec ({gain:.1f}x), "
          f"lane fill {gw.gateway_summary['lane_fill']:.0%}")
    assert gw.circuits_per_second > base.circuits_per_second, \
        "coalesced gateway must beat per-circuit dispatch"

    print("\n## open-loop Poisson arrivals (60 circuits/sec/client)")
    rep = poisson()
    s = rep.gateway_summary
    for t in s["tenants"]:
        print(f"{t['client']}: p50={t['p50_latency_s']:.2f}s "
              f"p99={t['p99_latency_s']:.2f}s cps={t['circuits_per_second']}")
    print(f"# lane fill {s['lane_fill']:.0%} over {s['batches']} batches "
          f"({s['size_flushes']} size / {s['deadline_flushes']} deadline flushes)")
    assert s["lane_fill"] >= 0.5, "open-loop lane fill must stay >= 50%"

    result = {
        "fig6": rows,
        "system_cps_uncoalesced": round(base.circuits_per_second, 2),
        "system_cps_gateway": round(gw.circuits_per_second, 2),
        "system_gain": round(gain, 2),
        "poisson": s,
    }
    if run_kernel:
        print("\n## real kernel: coalesced launch vs per-circuit launches")
        r = kernel()
        print(f"{r['n_circuits']} circuits: coalesced {r['coalesced_cps']} c/s "
              f"vs per-circuit {r['per_circuit_cps']} c/s ({r['speedup']})")
        result["kernel"] = r
    return result


if __name__ == "__main__":
    main()
