"""Serving-gateway throughput: cross-tenant circuit-bank coalescing vs the
per-circuit dispatch path, on the Fig-6-shaped multi-tenant workload.

Sections:

* ``fig6``    — 4 concurrent clients (5Q/1L, 5Q/2L, 7Q/1L, 7Q/2L) against 4
  heterogeneous workers (5/10/15/20 qubits), on the virtual clock.  The
  baseline is the paper's per-circuit co-managed dispatch; the gateway path
  coalesces compatible circuits across tenants into lane-aligned mega-batches
  (one Algorithm-2 task each, fused-kernel cost model).

* ``sync_vs_async`` — the same Fig-6 workload through the synchronous
  gateway (one serial dispatch ledger: batch execution head-of-line-blocks
  admission) vs the async counterpart (per-worker slot pipelines), on the
  virtual clock.  Acceptance: async circuits/sec >= sync.

* ``poisson`` — open-loop serving stand-in: each client's circuits arrive as
  a Poisson stream rather than an epoch burst, so the coalescer has to trade
  batch fill against the flush deadline.  Reports per-tenant p50/p99 latency,
  SLO attainment, and the lane-fill rate.

* ``kernel`` / ``async_kernel`` — real-execution sanity checks (no virtual
  clock): wall-clock circuits/sec of one coalesced Pallas launch vs
  per-circuit launches, and of the sync inline dispatcher vs the
  ``AsyncDispatcher`` worker pool (>= 2 slots) on the Fig-6 client mix,
  with per-tenant SLO attainment.

Run:  PYTHONPATH=src:. python benchmarks/gateway_throughput.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import paper_data as PD
from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation
from repro.comanager.worker import PAPER_RATES_GCP, WorkerConfig

CLIENTS = [("5q1l", 5, 1), ("5q2l", 5, 2), ("7q1l", 7, 1), ("7q2l", 7, 2)]
CONTENTION = 0.5  # same co-residency slowdown as benchmarks/multitenant.py


def workers():
    return [
        WorkerConfig(f"w{i+1}", q, contention=CONTENTION)
        for i, q in enumerate((5, 10, 15, 20))
    ]


def make_jobs(scale: float = 0.25):
    jobs = []
    for cid, qc, nl in CLIENTS:
        n = max(8, int(PD.N_CIRCUITS[(qc, nl)] * scale))
        jobs.append(
            tenancy.JobSpec(
                cid, qc, nl, n, service_override=1.0 / PAPER_RATES_GCP[(qc, nl)]
            )
        )
    return jobs


# ------------------------------------------------------------------- fig6
def fig6(scale: float = 0.25):
    """Coalesced gateway vs uncoalesced per-circuit dispatch, closed world."""
    common = dict(classical_overhead=0.01, assign_latency=PD.ASSIGN_LATENCY)
    base = SystemSimulation(
        workers(), make_jobs(scale), fair_queue=True, **common
    ).run()
    gw = SystemSimulation(
        workers(), make_jobs(scale), gateway=True, gateway_deadline=1.0, **common
    ).run()
    rows = []
    for cid, qc, nl in CLIENTS:
        jb, jg = base.jobs[cid], gw.jobs[cid]
        rows.append(
            {
                "client": cid,
                "cps_uncoalesced": round(jb.circuits_per_second, 2),
                "cps_gateway": round(jg.circuits_per_second, 2),
                "gain": f"{jg.circuits_per_second / jb.circuits_per_second:.1f}x",
            }
        )
    return base, gw, rows


# ---------------------------------------------------------- sync vs async
def sync_vs_async(scale: float = 0.25):
    """Fig-6 workload through the synchronous gateway (serial dispatch
    ledger) vs the async gateway (per-worker slot pipelines overlap batch
    dispatch across workers), virtual clock — deterministic, so the trend
    gate pins it."""
    common = dict(
        classical_overhead=0.01,
        assign_latency=PD.ASSIGN_LATENCY,
        gateway=True,
        gateway_deadline=1.0,
    )
    sync = SystemSimulation(workers(), make_jobs(scale), **common).run()
    asyn = SystemSimulation(
        workers(), make_jobs(scale), gateway_async=True, **common
    ).run()
    return {
        "sync_cps": round(sync.circuits_per_second, 2),
        "async_cps": round(asyn.circuits_per_second, 2),
        "async_over_sync": round(
            asyn.circuits_per_second / sync.circuits_per_second, 3
        ),
    }


# ---------------------------------------------------------------- poisson
#: serving tenants arrive in structural families — two tenants per circuit
#: shape — so the coalescer's cross-tenant packing actually has peers to
#: pack with (a tenant alone at 60 c/s can only ~half-fill a 128-lane batch
#: within the deadline; two tenants sharing a structure fill it).
POISSON_CLIENTS = [
    ("alice-5q", 5, 1),
    ("bob-5q", 5, 1),
    ("carol-7q", 7, 1),
    ("dave-7q", 7, 1),
]

#: end-to-end latency SLOs for the Poisson tenants (ms).  2000 ms keeps the
#: SLO flush budget (SLO_FLUSH_FRACTION * 2 s = 1 s) equal to the default
#: 1 s deadline — attainment is REPORTED without changing the flush policy.
POISSON_SLOS_MS = {cid: 2000.0 for cid, _, _ in POISSON_CLIENTS}


def poisson(
    rate_per_client: float = 60.0,
    n_per_client: int = 300,
    deadline: float = 1.0,
    seed: int = 0,
):
    """Open-loop arrivals: per-circuit Poisson streams instead of one burst."""
    rng = np.random.default_rng(seed)
    jobs, arrivals = [], {}
    for cid, qc, nl in POISSON_CLIENTS:
        jobs.append(
            tenancy.JobSpec(
                cid,
                qc,
                nl,
                n_per_client,
                service_override=1.0 / PAPER_RATES_GCP[(qc, nl)],
            )
        )
        arrivals[cid] = np.cumsum(
            rng.exponential(1.0 / rate_per_client, n_per_client)
        ).tolist()
    sim = SystemSimulation(
        workers(),
        jobs,
        gateway=True,
        gateway_deadline=deadline,
        arrivals=arrivals,
        tenant_slos_ms=POISSON_SLOS_MS,
        classical_overhead=0.01,
        assign_latency=PD.ASSIGN_LATENCY,
    )
    return sim.run()


# ------------------------------------------------------------------ chaos
#: canonical crash scenario for the fault-tolerance trend gates: w3 goes
#: silent at t=0.5s — right before the first deadline-flush wave lands on
#: the workers — and recovers at t=3.0s.  Batches stranded on it are
#: evicted after 3 missed heartbeats, migrate back through the coalescer,
#: and complete on the survivors.
CHAOS_FAILURES = {"w3": {"kind": "crash_recover", "at": 0.5, "recover_at": 3.0}}
CHAOS_SLO_MS = 5000.0


def chaos(scale: float = 0.25):
    """Fig-6 workload with a mid-run worker crash + recovery (virtual
    clock, deterministic).  The gated metrics prove both halves of the
    fault-tolerance story: batches really migrated off the dead worker
    (``migrated_batches``), and the system still finished every circuit
    within SLO (``completed_fraction``, ``slo_attainment``)."""
    jobs = make_jobs(scale)
    rep = SystemSimulation(
        workers(),
        jobs,
        gateway=True,
        gateway_deadline=1.0,
        heartbeat_period=0.3,
        classical_overhead=0.01,
        assign_latency=PD.ASSIGN_LATENCY,
        tenant_slos_ms={j.client_id: CHAOS_SLO_MS for j in jobs},
        worker_failures=CHAOS_FAILURES,
    ).run()
    s = rep.gateway_summary
    total = sum(j.n_circuits for j in jobs)
    completed = sum(r.n_circuits for r in rep.jobs.values())
    return {
        "migrated_batches": s.get("migrated_batches", 0),
        "migrated_circuits": s.get("migrated_circuits", 0),
        "completed_fraction": round(completed / total, 4),
        "slo_attainment": s.get("slo_attainment"),
        "evictions": len(rep.evictions),
        "cps": round(rep.circuits_per_second, 2),
        "makespan_s": round(rep.makespan, 3),
    }


# ----------------------------------------------------------------- kernel
def kernel(n: int = 128, qc: int = 5, n_layers: int = 1, seed: int = 0):
    """Real data plane: one coalesced launch vs n per-circuit launches."""
    import jax.numpy as jnp
    from repro.core import circuits
    from repro.kernels import ops as kops

    spec = circuits.build_quclassi_circuit(qc, n_layers)
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.uniform(0, np.pi, (n, spec.n_theta)), jnp.float32)
    data = jnp.asarray(rng.uniform(0, np.pi, (n, spec.n_data)), jnp.float32)

    kops.vqc_fidelity(spec, theta, data).block_until_ready()  # warm both jits
    kops.vqc_fidelity(spec, theta[:1], data[:1]).block_until_ready()

    t0 = time.perf_counter()
    f_big = kops.vqc_fidelity(spec, theta, data).block_until_ready()
    t_coalesced = time.perf_counter() - t0

    t0 = time.perf_counter()
    singles = [
        kops.vqc_fidelity(spec, theta[i : i + 1], data[i : i + 1]) for i in range(n)
    ]
    f_per = np.concatenate([np.asarray(s) for s in singles])
    t_single = time.perf_counter() - t0

    np.testing.assert_allclose(np.asarray(f_big), f_per, atol=1e-6)
    return {
        "n_circuits": n,
        "coalesced_cps": round(n / t_coalesced, 1),
        "per_circuit_cps": round(n / t_single, 1),
        "speedup": f"{t_single / t_coalesced:.1f}x",
    }


#: (client, qc, layers, slo_ms) for the real-execution async section: the
#: Fig-6 client mix with latency SLOs attached.
ASYNC_CLIENTS = [
    ("5q1l", 5, 1, 4000.0),
    ("5q2l", 5, 2, 4000.0),
    ("7q1l", 7, 1, 8000.0),
    ("7q2l", 7, 2, 8000.0),
]


def async_kernel(
    n_per_client: int = 256,
    slots_per_worker: int = 2,
    deadline: float = 0.25,
    seed: int = 0,
):
    """Real data plane, Fig-6 client mix: the sync dispatcher executes every
    mega-batch inline (serial kernel launches), the async dispatcher overlaps
    launches across per-worker slots.  Reports wall-clock circuits/sec for
    both and per-tenant SLO attainment from the async run."""
    import jax.numpy as jnp
    from repro.core import circuits
    from repro.serve import GatewayRuntime

    rng = np.random.default_rng(seed)
    streams = []
    for cid, qc, nl, slo in ASYNC_CLIENTS:
        spec = circuits.build_quclassi_circuit(qc, nl)
        theta = jnp.asarray(
            rng.uniform(0, np.pi, (n_per_client, spec.n_theta)), jnp.float32
        )
        data = jnp.asarray(
            rng.uniform(0, np.pi, (n_per_client, spec.n_data)), jnp.float32
        )
        streams.append((cid, spec, theta, data, slo))

    def run(mode: str):
        rt = GatewayRuntime(
            target=128, deadline=deadline, mode=mode, slots_per_worker=slots_per_worker
        )
        try:
            for cid, spec, theta, data, slo in streams:
                rt.gateway.register_client(cid, slo_ms=slo)
            # warm the per-spec kernel jits so both modes time execution,
            # not compilation
            for _, spec, theta, data, _ in streams:
                rt.dispatcher.kernel(spec, theta[:1], data[:1])
            t0 = time.perf_counter()
            futures = []
            for i in range(n_per_client):  # interleaved open-loop streams
                for cid, spec, theta, data, _ in streams:
                    futures.append(
                        rt.gateway.submit(
                            cid, spec, (theta[i], data[i]), now=rt.dispatcher.clock()
                        )
                    )
                rt.dispatcher.kick()
            rt.dispatcher.drain()
            wall = time.perf_counter() - t0
            assert all(f.done for f in futures)
            summary = rt.telemetry.summary()
        finally:
            rt.close()
        return len(futures) / wall, summary

    sync_cps, _ = run("sync")
    async_cps, summary = run("async")
    return {
        "n_circuits": n_per_client * len(ASYNC_CLIENTS),
        "worker_slots": 4 * slots_per_worker,
        "sync_cps": round(sync_cps, 1),
        "async_cps": round(async_cps, 1),
        "async_over_sync": round(async_cps / sync_cps, 2),
        "slo_attainment": {
            t["client"]: t.get("slo_attainment") for t in summary["tenants"]
        },
    }


def main(
    run_kernel: bool = True, scale: float = 0.25, trace_path: str | None = None
):
    print("## fig6-shaped workload: 4 clients x 4 workers (virtual clock)")
    base, gw, rows = fig6(scale)
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
    gain = gw.circuits_per_second / base.circuits_per_second
    print(
        f"# system: {base.circuits_per_second:.1f} -> "
        f"{gw.circuits_per_second:.1f} circuits/sec ({gain:.1f}x), "
        f"lane fill {gw.gateway_summary['lane_fill']:.0%}"
    )
    assert (
        gw.circuits_per_second > base.circuits_per_second
    ), "coalesced gateway must beat per-circuit dispatch"

    print("\n## sync vs async dispatch (virtual clock, per-worker slot pipelines)")
    sva = sync_vs_async(scale)
    print(
        f"# sync {sva['sync_cps']} c/s -> async {sva['async_cps']} c/s "
        f"({sva['async_over_sync']}x)"
    )
    assert (
        sva["async_cps"] >= sva["sync_cps"]
    ), "async dispatcher must sustain >= the sync path's circuits/sec"

    print("\n## open-loop Poisson arrivals (60 circuits/sec/client, 2 s latency SLO)")
    rep = poisson()
    s = rep.gateway_summary
    for t in s["tenants"]:
        print(
            f"{t['client']}: p50={t['p50_latency_s']:.2f}s "
            f"p99={t['p99_latency_s']:.2f}s cps={t['circuits_per_second']} "
            f"slo_attainment={t.get('slo_attainment')}"
        )
    print(
        f"# lane fill {s['lane_fill']:.0%} over {s['batches']} batches "
        f"({s['size_flushes']} size / {s['deadline_flushes']} deadline "
        f"flushes), slo attainment {s.get('slo_attainment')}"
    )
    assert s["lane_fill"] >= 0.5, "open-loop lane fill must stay >= 50%"

    # stage-latency breakdown from the lifecycle traces: virtual-clock, so
    # the shares and event counts are machine-independent and trend-gated.
    obs = s["observability"]
    stages = obs["stages"]
    shares = {
        m: stages.get(f"{m}_share", 0.0)
        for m in (
            "queue_wait", "coalesce_wait", "place_wait", "dispatch_lag", "execute"
        )
    }
    print(
        f"# trace: {obs['events']} events over {obs['records']} records; "
        f"e2e share "
        + " ".join(f"{m}={v:.0%}" for m, v in shares.items())
    )
    if trace_path is not None:
        rep.trace.export_chrome_trace(trace_path)
        print(f"[artifact] wrote {trace_path} (open in ui.perfetto.dev)")

    print("\n## chaos: mid-run worker crash + recovery (virtual clock)")
    ch = chaos(scale)
    print(
        f"# {ch['migrated_batches']} batches ({ch['migrated_circuits']} "
        f"circuits) migrated off the dead worker, "
        f"{ch['completed_fraction']:.0%} of circuits completed, "
        f"slo attainment {ch['slo_attainment']}, "
        f"makespan {ch['makespan_s']}s"
    )
    assert (
        ch["completed_fraction"] == 1.0
    ), "every circuit must survive the worker crash"
    assert (
        ch["migrated_batches"] >= 1
    ), "the canonical crash scenario must exercise the migration path"

    result = {
        "fig6": rows,
        "system_cps_uncoalesced": round(base.circuits_per_second, 2),
        "system_cps_gateway": round(gw.circuits_per_second, 2),
        "system_gain": round(gain, 2),
        "sync_vs_async": sva,
        "poisson": s,
        "chaos": ch,
    }
    if run_kernel:
        print("\n## real kernel: coalesced launch vs per-circuit launches")
        r = kernel()
        print(
            f"{r['n_circuits']} circuits: coalesced {r['coalesced_cps']} c/s "
            f"vs per-circuit {r['per_circuit_cps']} c/s ({r['speedup']})"
        )
        result["kernel"] = r

        print(
            "\n## real kernel: sync inline dispatcher vs async worker pool "
            "(Fig-6 client mix)"
        )
        ra = async_kernel()
        print(
            f"{ra['n_circuits']} circuits over {ra['worker_slots']} worker "
            f"slots: sync {ra['sync_cps']} c/s vs async {ra['async_cps']} "
            f"c/s ({ra['async_over_sync']}x), "
            f"slo attainment {ra['slo_attainment']}"
        )
        result["async_kernel"] = ra
    return result


if __name__ == "__main__":
    main()
