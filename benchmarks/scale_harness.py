"""Scale harness: storm sweep -> knee -> calibrated admission -> artifact.

Drives the ``repro.scale`` pipeline end to end and emits
``BENCH_scale.json`` for the trend gate plus ``trace_scale_sweep.json``
(the full knee-sweep curve) as a CI artifact:

1. generate a seeded multi-population arrival storm (interactive / batch /
   bursty tenants with priority tiers, SLO classes and fair-share weights);
2. sweep offered load on the virtual clock, replaying the storm through
   the serving gateway at each multiplier;
3. locate the throughput knee and the attainment cliff past it;
4. calibrate the gateway's weighted-fair global admission cap at the knee
   (Little's law) and verify a past-knee storm actually sheds load with it;
5. re-run the sweep at the same seed and require bit-identical results
   (the determinism gate).

Everything gated is virtual-clock deterministic; the harness's own wall
time and timer breakdown ride along informationally only.

Usage:
    PYTHONPATH=src python -m benchmarks.scale_harness           # CI mode
    PYTHONPATH=src python -m benchmarks.scale_harness --full    # 10k tenants
    PYTHONPATH=src python -m benchmarks.scale_harness --real    # + kernels
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

#: CI recipe: ~1k tenants on the 4-worker quartet saturates inside a minute
#: while still crossing the knee.  --full widens to 10k tenants on the
#: 8-worker fleet (the tentpole-scale storm; minutes, not CI).
CI_DEFAULTS = dict(
    tenants=1000,
    rate_per_tenant=0.4,
    slo_scale=2.0,
    duration_s=20.0,
    seed=7,
    n_replicas=1,
    loads=(0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0),
    efficiency_floor=0.80,
    attainment_floor=0.99,
    overload=1.6,
    slack=0.5,
)
FULL_OVERRIDES = dict(
    tenants=10_000,
    n_replicas=2,
    loads=(0.125, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5),
)


def build_spec(cfg):
    from repro.scale import WorkloadSpec, standard_populations

    return WorkloadSpec(
        populations=standard_populations(
            cfg["tenants"],
            rate_per_tenant=cfg["rate_per_tenant"],
            slo_scale=cfg["slo_scale"],
        ),
        duration_s=cfg["duration_s"],
        seed=cfg["seed"],
    )


def run_sweep(cfg, timer, progress=print):
    from repro.scale import default_fleet, find_knee, sweep

    spec = build_spec(cfg)
    fleet = default_fleet(cfg["n_replicas"])
    points = sweep(
        spec,
        cfg["loads"],
        timer=timer,
        progress=progress,
        workers=fleet,
    )
    report = find_knee(
        points,
        efficiency_floor=cfg["efficiency_floor"],
        attainment_floor=cfg["attainment_floor"],
    )
    return spec, fleet, report


def run_real(cfg, timer):
    """Small real-kernel mix (wall clock, machine-dependent: never gated)."""
    from repro.scale import WorkloadSpec, replay_real, standard_populations

    spec = WorkloadSpec(
        populations=standard_populations(
            24, rate_per_tenant=2.0, slo_scale=cfg["slo_scale"]
        ),
        duration_s=3.0,
        seed=cfg["seed"],
    )
    with timer.time("real"):
        res = replay_real(spec.generate())
    return res.row()


def main(argv=None) -> int:
    from repro.scale import CumulativeTimer, config_diff, verify_admission

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--full",
        action="store_true",
        help="10k-tenant storm on the 8-worker fleet (minutes)",
    )
    ap.add_argument(
        "--tenants", type=int, default=None, help="override the tenant population size"
    )
    ap.add_argument("--seed", type=int, default=None, help="override the storm seed")
    ap.add_argument(
        "--out-dir", default=".", help="directory for BENCH_scale.json + sweep trace"
    )
    ap.add_argument(
        "--skip-determinism",
        action="store_true",
        help="skip the same-seed double run (halves wall time; "
        "the determinism gate then reports 0)",
    )
    ap.add_argument(
        "--real",
        action="store_true",
        help="also replay a small mix on real kernels "
        "(wall clock, informational only)",
    )
    args = ap.parse_args(argv)

    cfg = dict(CI_DEFAULTS)
    if args.full:
        cfg.update(FULL_OVERRIDES)
    if args.tenants is not None:
        cfg["tenants"] = args.tenants
    if args.seed is not None:
        cfg["seed"] = args.seed
    diff = config_diff(CI_DEFAULTS, cfg)
    if diff:
        print("config deviates from CI defaults:")
        for line in diff:
            print(f"  {line}")

    t0 = time.time()
    timer = CumulativeTimer()
    spec, fleet, report = run_sweep(cfg, timer)
    knee, cliff = report.knee, report.cliff
    print(
        f"\nknee: load {knee.load:g} -> offered {knee.offered_cps:.0f} c/s, "
        f"achieved {knee.achieved_cps:.0f} c/s, p99 {knee.p99_latency_s:.2f}s, "
        f"attainment {knee.slo_attainment}"
    )
    if cliff is not None:
        print(
            f"cliff: load {cliff.load:g} -> efficiency {cliff.efficiency:.2f}, "
            f"attainment {cliff.slo_attainment}"
        )
    if not report.saturated:
        print(
            "ERROR: sweep never saturated — no knee found; widen the load "
            "range or shrink the fleet",
            file=sys.stderr,
        )
        return 1

    near80 = report.point_near_offered(0.8 * knee.offered_cps)

    with timer.time("admission"):
        admission = verify_admission(
            spec,
            report,
            overload=cfg["overload"],
            slack=cfg["slack"],
            workers=fleet,
        )
    print(
        f"admission: cap {admission['max_system_pending']} -> "
        f"reject {admission['reject_fraction']:.1%} at "
        f"{cfg['overload']:g}x knee, attainment "
        f"{admission['attainment_uncapped']} -> "
        f"{admission['attainment_admitted']} for admitted"
    )

    repeat_identical = 0
    if not args.skip_determinism:
        with timer.time("determinism"):
            _, _, report2 = run_sweep(cfg, CumulativeTimer(), progress=None)
        repeat_identical = int(report.to_dict() == report2.to_dict())
        print(
            f"determinism: same-seed double run "
            f"{'identical' if repeat_identical else 'DIVERGED'}"
        )
        if not repeat_identical:
            print("ERROR: same-seed sweep not reproducible", file=sys.stderr)

    payload = {
        "config": {k: list(v) if isinstance(v, tuple) else v for k, v in cfg.items()},
        "config_diff_from_ci_defaults": diff,
        "knee": knee.row(),
        "cliff": cliff.row() if cliff is not None else None,
        "p99_at_80pct_knee_s": round(near80.p99_latency_s, 4),
        "attainment_at_knee": knee.slo_attainment,
        "admission": admission,
        "determinism": {"repeat_identical": repeat_identical},
        "sweep": [p.row() for p in report.points],
        "harness": {"wall_s": round(time.time() - t0, 1), "timers": timer.stats()},
    }
    if args.real:
        payload["real_kernels"] = run_real(cfg, timer)
        payload["harness"]["timers"] = timer.stats()
        print(f"real kernels: {payload['real_kernels']}")

    os.makedirs(args.out_dir, exist_ok=True)
    bench_path = os.path.join(args.out_dir, "BENCH_scale.json")
    with open(bench_path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[artifact] wrote {bench_path}")
    trace_path = os.path.join(args.out_dir, "trace_scale_sweep.json")
    with open(trace_path, "w") as f:
        json.dump(
            {"config": payload["config"], "knee_report": report.to_dict()},
            f,
            indent=2,
            default=float,
        )
    print(f"[artifact] wrote {trace_path}")
    print(f"\nscale harness done in {time.time() - t0:.0f}s")
    return 0 if repeat_identical or args.skip_determinism else 1


if __name__ == "__main__":
    sys.exit(main())
