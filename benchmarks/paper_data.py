"""The paper's published numbers (Figs 3-6, §IV) + service-time calibration.

Runtime model (matches the paper's Algorithm-1 lockstep dispatch loop):
    T(w) = N * t_cl + (N / w) * (t_q + lat)
where t_cl is the serial classical per-circuit cost on the manager
(logical-circuit generation + quantum state analysis), t_q the quantum
service time, lat the dispatch latency.  We calibrate (t_cl, t_q) per
(qc, layers, env) from the paper's OWN 1-worker and 4-worker endpoints and
then let the event-driven simulator produce every intermediate point — the
2-worker values are therefore predictions, compared against the paper's.
"""
from __future__ import annotations

import dataclasses

#: circuits per epoch (§IV-C1)
N_CIRCUITS = {
    (5, 1): 1440,
    (5, 2): 2880,
    (5, 3): 4320,
    (7, 1): 2016,
    (7, 2): 4032,
    (7, 3): 6048,
}

#: paper epoch runtimes, seconds: (qc, layers) -> {workers: seconds}
#: 2-worker entries derived from circuits/sec where runtime text omits them.
FIG3_RUNTIME_5Q_IBMQ = {
    (5, 1): {1: 94.7, 2: 85.2, 4: 73.1},
    (5, 2): {1: 467.9, 2: 450.0, 4: 418.6},
    (5, 3): {1: 749.8, 2: 651.7, 4: 569.8},
}
FIG4_RUNTIME_7Q_IBMQ = {
    (7, 1): {1: 163.0, 2: 149.3, 4: 134.3},
    (7, 2): {1: 566.5, 2: 560.0, 4: 510.8},
    (7, 3): {1: 1366.1, 2: 1303.9, 4: 1246.5},
}
#: paper circuits/sec (Figs 3b, 4b)
FIG3_CPS_5Q_IBMQ = {
    (5, 1): {1: 15.2, 2: 16.9, 4: 19.7},
    (5, 2): {1: 6.2, 2: 6.4, 4: 6.6},
    (5, 3): {1: 5.9, 2: 6.6, 4: 7.6},
}
FIG4_CPS_7Q_IBMQ = {
    (7, 1): {1: 12.4, 2: 13.5, 4: 15.0},
    (7, 2): {1: 7.1, 2: 7.2, 4: 7.9},
    (7, 3): {1: 4.4, 2: 4.6, 4: 4.8},
}
#: Fig 5b controlled-env (GCP e2-medium) circuits/sec, 5-qubit
FIG5_CPS_5Q_GCP = {
    (5, 1): {1: 3.8, 2: 4.2, 4: 5.2},
    (5, 3): {1: 2.4, 2: 3.1, 4: 4.4},
}
#: Fig 5a runtime reductions of the 4-worker system vs 1- and 2-worker
FIG5_REDUCTION_4W = {
    (5, 1): (0.271, 0.189),
    (5, 2): (0.373, 0.315),
    (5, 3): (0.432, 0.300),
}
#: Fig 6 multi-tenant vs single-tenant runtime reduction
FIG6_REDUCTION = {"5q1l": 0.687, "7q2l": 0.082}

ASSIGN_LATENCY = 0.005


@dataclasses.dataclass(frozen=True)
class Calibration:
    qc: int
    layers: int
    t_classical: float      # serial manager cost per circuit
    t_quantum: float        # worker service time per circuit

    @property
    def n_circuits(self) -> int:
        return N_CIRCUITS[(self.qc, self.layers)]


def calibrate(qc: int, layers: int, runtimes: dict[int, float]) -> Calibration:
    """Solve T(w) = N t_cl + (N/w)(t_q + lat) from the w=1 and w=4 points."""
    n = N_CIRCUITS[(qc, layers)]
    t1, t4 = runtimes[1], runtimes[4]
    tq_lat = 4.0 * (t1 - t4) / (3.0 * n)
    t_q = max(tq_lat - ASSIGN_LATENCY, 1e-4)
    t_cl = t1 / n - tq_lat
    return Calibration(qc, layers, t_cl, t_q)


def calibrate_from_cps(qc: int, layers: int, cps: dict[int, float]) -> Calibration:
    n = N_CIRCUITS[(qc, layers)]
    return calibrate(qc, layers, {w: n / r for w, r in cps.items()})
