"""Figs 3 & 4: epoch runtime + circuits/sec vs worker count on (simulated)
IBM-Q backends — the uncontrolled environment.

The workers are unrestricted (no qubit cap, like IBM-Q's simulation
backends); speedup comes purely from distributing the bank, capped by the
serial classical side — exactly the paper's diminishing-returns shape.
"""
from __future__ import annotations

from benchmarks import paper_data as PD
from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation, homogeneous_workers


def run_config(qc: int, layers: int, n_workers: int, cal: PD.Calibration):
    jobs = [
        tenancy.JobSpec(
            "client", qc, layers, cal.n_circuits, service_override=cal.t_quantum
        )
    ]
    workers = homogeneous_workers(n_workers, max_qubits=64, contention=0.0)
    sim = SystemSimulation(
        workers,
        jobs,
        lockstep=True,
        classical_overhead=cal.t_classical,
        assign_latency=PD.ASSIGN_LATENCY,
    )
    return sim.run()


def rows(figure: str = "fig3"):
    table = PD.FIG3_RUNTIME_5Q_IBMQ if figure == "fig3" else PD.FIG4_RUNTIME_7Q_IBMQ
    cps_table = PD.FIG3_CPS_5Q_IBMQ if figure == "fig3" else PD.FIG4_CPS_7Q_IBMQ
    out = []
    for (qc, layers), runtimes in sorted(table.items()):
        cal = PD.calibrate(qc, layers, runtimes)
        for w in (1, 2, 4):
            rep = run_config(qc, layers, w, cal)
            paper_t = runtimes[w]
            paper_cps = cps_table[(qc, layers)][w]
            out.append(
                {
                    "figure": figure,
                    "qc": qc,
                    "layers": layers,
                    "workers": w,
                    "sim_runtime_s": round(rep.makespan, 1),
                    "paper_runtime_s": paper_t,
                    "runtime_err": round(abs(rep.makespan - paper_t) / paper_t, 3),
                    "sim_cps": round(rep.circuits_per_second, 2),
                    "paper_cps": paper_cps,
                    "cps_err": round(
                        abs(rep.circuits_per_second - paper_cps) / paper_cps, 3
                    ),
                }
            )
    return out


def main():
    all_rows = rows("fig3") + rows("fig4")
    keys = list(all_rows[0])
    print(",".join(keys))
    for r in all_rows:
        print(",".join(str(r[k]) for k in keys))
    # headline claims
    for fig, tab in (
        ("fig3", PD.FIG3_RUNTIME_5Q_IBMQ),
        ("fig4", PD.FIG4_RUNTIME_7Q_IBMQ),
    ):
        worst = max(r["runtime_err"] for r in all_rows if r["figure"] == fig)
        print(f"# {fig}: worst relative runtime error vs paper = {worst:.1%}")
    return all_rows


if __name__ == "__main__":
    main()
