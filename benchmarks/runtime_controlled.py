"""Fig 5: one client, multiple circuits, controlled environment (GCP
e2-medium VMs, 1-manager + 1/2/4 quantum workers, 5-qubit circuits).

Workers here ARE qubit-capped (5 qubits — one circuit resident at a time),
matching the e2-medium single-core simulators.
"""
from __future__ import annotations

from benchmarks import paper_data as PD
from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation, homogeneous_workers


def run_config(qc, layers, n_workers, cal):
    jobs = [
        tenancy.JobSpec(
            "client", qc, layers, cal.n_circuits, service_override=cal.t_quantum
        )
    ]
    workers = homogeneous_workers(n_workers, max_qubits=qc, contention=0.0)
    sim = SystemSimulation(
        workers,
        jobs,
        lockstep=True,
        classical_overhead=cal.t_classical,
        assign_latency=PD.ASSIGN_LATENCY,
    )
    return sim.run()


def rows():
    out = []
    for (qc, layers), cps in sorted(PD.FIG5_CPS_5Q_GCP.items()):
        cal = PD.calibrate_from_cps(qc, layers, cps)
        results = {}
        for w in (1, 2, 4):
            rep = run_config(qc, layers, w, cal)
            results[w] = rep
            out.append(
                {
                    "figure": "fig5",
                    "qc": qc,
                    "layers": layers,
                    "workers": w,
                    "sim_runtime_s": round(rep.makespan, 1),
                    "sim_cps": round(rep.circuits_per_second, 2),
                    "paper_cps": cps[w],
                    "cps_err": round(
                        abs(rep.circuits_per_second - cps[w]) / cps[w], 3
                    ),
                }
            )
        # 4-worker reduction vs 1- and 2-worker (Fig 5a's headline numbers)
        red1 = 1 - results[4].makespan / results[1].makespan
        red2 = 1 - results[4].makespan / results[2].makespan
        p1, p2 = PD.FIG5_REDUCTION_4W[(qc, layers)]
        out.append(
            {
                "figure": "fig5",
                "qc": qc,
                "layers": layers,
                "workers": "4v1/4v2",
                "sim_runtime_s": f"{red1:.1%}/{red2:.1%}",
                "sim_cps": "",
                "paper_cps": f"{p1:.1%}/{p2:.1%}",
                "cps_err": "",
            }
        )
    return out


def main():
    all_rows = rows()
    keys = list(all_rows[0])
    print(",".join(keys))
    for r in all_rows:
        print(",".join(str(r[k]) for k in keys))
    return all_rows


if __name__ == "__main__":
    main()
