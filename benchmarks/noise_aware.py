"""BEYOND PAPER: noise-aware scheduling (the paper's §V limitation #2 —
"our system does not take noise into account when scheduling... quantum
noise has a significant impact on state fidelities").

Setup: heterogeneous workers where the BIGGEST machines are the NOISIEST
(the realistic NISQ trade-off), one client's 5q/2L circuit bank.  The CRU
policy (Algorithm 2) happily routes everything to big/fast machines; the
noise-aware policy prefers clean machines among capacity-feasible
candidates, trading some runtime for fidelity retention.

Also quantifies the END-TO-END effect: gradient error of a parameter-shift
step when each circuit's fidelity passes through its worker's depolarizing
channel, under both schedules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.comanager import tenancy
from repro.comanager.simulation import SystemSimulation
from repro.comanager.worker import WorkerConfig

WORKERS = [
    # clean but small/slow ... noisy but big/fast
    WorkerConfig("w_clean_a", 5, speed=0.8, error_rate=0.0005),
    WorkerConfig("w_clean_b", 5, speed=0.8, error_rate=0.001),
    WorkerConfig("w_mid", 10, speed=1.0, error_rate=0.004),
    WorkerConfig("w_big_noisy", 20, speed=1.3, error_rate=0.012),
]


def run(policy: str, n_circuits: int = 480, fidelity_floor: float = 0.0):
    jobs = [tenancy.JobSpec("client", 5, 2, n_circuits, service_override=0.33)]
    sim = SystemSimulation(
        WORKERS,
        jobs,
        policy=policy,
        fair_queue=True,
        fidelity_floor=fidelity_floor,
        classical_overhead=0.01,
    )
    rep = sim.run()
    return sim, rep


def gradient_error(sim, rep):
    """Propagate each circuit's depolarization into a real shift-rule
    gradient and compare against the ideal gradient."""
    from repro.core import quclassi, shift_rule
    from repro.core.quclassi import QuClassiConfig
    from repro.data import mnist

    cfg = QuClassiConfig(qc=5, n_layers=2)
    x, y = mnist.make_pair_dataset(1, 5, n_per_class=4, seed=0)
    xb, yb = jnp.asarray(x[:4]), jnp.asarray(y[:4])
    params = quclassi.init_params(cfg, jax.random.PRNGKey(0))
    banks, _ = quclassi.build_class_banks(cfg, params, xb)
    bank = banks[0]

    # per-bank-row retention from the schedule (cycled to bank length)
    reg = sim.manager.task_registry
    rets = []
    for _, tid, wid in rep.assignments:
        w = sim.workers[wid]
        rets.append((1.0 - w.cfg.error_rate) ** reg[tid].depth)
    rets = np.resize(np.array(rets), bank.n_circuits)

    ideal = shift_rule.default_executor(cfg.spec)(bank.theta, bank.data)
    # depolarizing channel on the ancilla readout: F = 2*P0-1 -> retention*F
    noisy = jnp.asarray(rets, jnp.float32) * ideal
    onehot = jax.nn.one_hot(yb, 2)[:, 0]
    _, g_ideal, _ = shift_rule.assemble_gradient(
        cfg.spec, bank, ideal, jnp.repeat(onehot, cfg.n_patches)
    )
    _, g_noisy, _ = shift_rule.assemble_gradient(
        cfg.spec, bank, noisy, jnp.repeat(onehot, cfg.n_patches)
    )
    denom = float(jnp.linalg.norm(g_ideal)) or 1.0
    return float(jnp.linalg.norm(g_noisy - g_ideal)) / denom


def rows():
    out = []
    for policy, floor in (
        ("cru", 0.0),
        ("noise_aware", 0.85),
        ("noise_aware", 0.90),
        ("noise_aware", 0.97),
    ):
        sim, rep = run(policy, fidelity_floor=floor)
        out.append(
            {
                "policy": f"{policy}" + (f"(floor={floor})" if floor else ""),
                "makespan_s": round(rep.makespan, 1),
                "cps": round(rep.circuits_per_second, 2),
                "fidelity_retention": round(rep.fidelity_retention, 4),
                "rel_gradient_error": round(gradient_error(sim, rep), 4),
            }
        )
    return out


def main():
    all_rows = rows()
    keys = list(all_rows[0])
    print(",".join(keys))
    for r in all_rows:
        print(",".join(str(r[k]) for k in keys))
    cru, na = all_rows[0], all_rows[-1]
    print(
        f"# noise-aware scheduling (strictest floor): retention "
        f"{cru['fidelity_retention']} -> {na['fidelity_retention']}, "
        f"gradient error {cru['rel_gradient_error']} -> "
        f"{na['rel_gradient_error']}, at "
        f"{na['makespan_s'] / cru['makespan_s']:.2f}x runtime"
    )
    return all_rows


if __name__ == "__main__":
    main()
