"""Kernel microbenchmark (beyond paper): fused Pallas VQC kernel vs the
per-gate pure-JAX simulator on a circuit batch, plus the shift-structured
circuit-bank section (implicit ``ShiftBank`` + prefix-reuse kernel vs the
materialized bank).

On CPU the Pallas kernels run in interpret mode, so WALL TIME here is not
the TPU story; the structural wins are analytic:

  * gate fusion      — per-gate execution round-trips the statevector batch
    through HBM once per gate, the fused kernel once per circuit;
  * shift structure  — the materialized bank re-simulates every gate of all
    (1 + 2P) * B rows and reads (P + D) * (1 + 2P) angle floats per sample;
    the prefix-reuse kernel runs one data-register pass, one checkpointed
    forward + one reversed-suffix backward pass over the trainable register,
    and ONE gate + one inner product per (param, shift) variant, reading
    (P + D) floats per sample.

We report measured wall time AND the analytic ratios the roofline uses.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits, shift_rule
from repro.kernels import ops, ref
from repro.kernels import vqc_statevector as K


def time_fn(fn, *args, iters: int = 3) -> float:
    out = fn(*args)                      # warm up ONCE, bind the result
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def hbm_bytes(qc: int, n_ops: int, batch: int, fused: bool) -> int:
    """Statevector traffic: (re+im) * 4 B * 2^qc per read+write round trip."""
    state = 2 * 4 * (2**qc) * batch
    trips = 2 if fused else 2 * n_ops          # read+write once vs per gate
    return state * trips


def rows(batch: int = 512):
    out = []
    for qc in (5, 7):
        for nl in (1, 3):
            spec = circuits.build_quclassi_circuit(qc, nl)
            key = jax.random.PRNGKey(0)
            theta = jax.random.uniform(key, (batch, spec.n_theta), jnp.float32)
            data = jax.random.uniform(key, (batch, spec.n_data), jnp.float32)

            fused = jax.jit(lambda t, d: ops.vqc_fidelity(spec, t, d))
            pergate = jax.jit(lambda t, d: ref.vqc_fidelity_ref(spec, t, d))
            t_fused = time_fn(fused, theta, data)
            t_ref = time_fn(pergate, theta, data)
            err = float(jnp.abs(fused(theta, data) - pergate(theta, data)).max())

            bf = hbm_bytes(qc, len(spec.ops), batch, fused=True)
            bp = hbm_bytes(qc, len(spec.ops), batch, fused=False)
            out.append(
                {
                    "qc": qc,
                    "layers": nl,
                    "batch": batch,
                    "n_gates": len(spec.ops),
                    "fused_us_per_circuit": round(t_fused / batch * 1e6, 2),
                    "pergate_us_per_circuit": round(t_ref / batch * 1e6, 2),
                    "max_err": f"{err:.1e}",
                    "hbm_bytes_fused": bf,
                    "hbm_bytes_pergate": bp,
                    "traffic_ratio": round(bp / bf, 1),
                }
            )
    return out


def shift_rows(batch: int = 64, four_term: bool = False):
    """Implicit ShiftBank through the prefix-reuse kernel vs the same bank
    materialized through the standard fused kernel."""
    out = []
    for qc in (5, 7):
        for nl in (1, 3):
            spec = circuits.build_quclassi_circuit(qc, nl)
            key = jax.random.PRNGKey(1)
            theta = jax.random.uniform(
                key, (spec.n_theta,), jnp.float32, minval=0.0, maxval=np.pi
            )
            data = jax.random.uniform(
                jax.random.fold_in(key, 1),
                (batch, spec.n_data),
                jnp.float32,
                minval=0.0,
                maxval=np.pi,
            )
            bank = shift_rule.build_shift_bank(theta, data, four_term=four_term)
            mat = bank.materialize()

            implicit = jax.jit(
                lambda t, d: ops.vqc_fidelity_shiftbank(spec, t, d, four_term)
            )
            materialized = jax.jit(lambda t, d: ops.vqc_fidelity(spec, t, d))
            t_impl = time_fn(implicit, bank.theta, bank.data)
            t_mat = time_fn(materialized, mat.theta, mat.data)
            err = float(
                jnp.abs(
                    implicit(bank.theta, bank.data) - materialized(mat.theta, mat.data)
                ).max()
            )
            # assert on the RAW error: the displayed string is rounded to one
            # significant figure and useless at the 1e-5 boundary.
            assert err < 1e-5, (qc, nl, err)

            stats = K.shift_bank_stats(spec, batch, four_term)
            out.append(
                {
                    "qc": qc,
                    "layers": nl,
                    "batch": batch,
                    "n_params": spec.n_theta,
                    "n_circuits": bank.n_circuits,
                    "implicit_us_per_circuit": round(t_impl / bank.n_circuits * 1e6, 2),
                    "materialized_us_per_circuit": round(
                        t_mat / bank.n_circuits * 1e6, 2
                    ),
                    "max_err": f"{err:.1e}",
                    "gate_apps_implicit": stats["gate_apps_implicit"],
                    "gate_apps_materialized": stats["gate_apps_materialized"],
                    "gate_apps_ratio": stats["gate_apps_ratio"],
                    "angle_bytes_implicit": stats["angle_bytes_implicit"],
                    "angle_bytes_materialized": stats["angle_bytes_materialized"],
                    "angle_bytes_ratio": stats["angle_bytes_ratio"],
                }
            )
    return out


def multibank_rows(batch: int = 64, qc: int = 7, nl: int = 3):
    """Fused multi-bank launches: K same-spec banks (the paper's Fig-6
    multi-tenant setting — concurrent tenants training one circuit spec)
    executed as ONE prefix-reuse launch vs K per-bank launches.  Launch
    counts and lane fill are analytic (machine-independent, trend-gated);
    wall time is CPU interpret-mode color only."""
    spec = circuits.build_quclassi_circuit(qc, nl)
    out = []
    for k in (1, 2, 4, 8):
        key = jax.random.PRNGKey(k)
        banks = []
        for i in range(k):
            theta = jax.random.uniform(
                jax.random.fold_in(key, i),
                (spec.n_theta,),
                jnp.float32,
                minval=0.0,
                maxval=np.pi,
            )
            data = jax.random.uniform(
                jax.random.fold_in(key, 100 + i),
                (batch, spec.n_data),
                jnp.float32,
                minval=0.0,
                maxval=np.pi,
            )
            banks.append(shift_rule.build_shift_bank(theta, data))
        thetas = tuple(b.theta for b in banks)
        datas = tuple(b.data for b in banks)
        group_sets = tuple(tuple(range(b.n_groups)) for b in banks)

        fused = jax.jit(
            lambda ts, ds: ops.vqc_fidelity_shiftgroups_multibank(
                spec, ts, ds, False, group_sets
            )
        )
        per_bank = jax.jit(
            lambda ts, ds: tuple(
                ops.vqc_fidelity_shiftgroups(spec, t, d, False) for t, d in zip(ts, ds)
            )
        )
        t_fused = time_fn(fused, thetas, datas)
        t_per = time_fn(per_bank, thetas, datas)
        got = fused(thetas, datas)
        want = per_bank(thetas, datas)
        err = max(float(jnp.abs(g - w).max()) for g, w in zip(got, want))
        assert err < 1e-5, (k, err)

        stats = K.multibank_stats(spec, [batch] * k)
        # acceptance: the fused path collapses K per-bank launches into one
        # (>= 2x analytic launch-count reduction at K = 4) without losing
        # lane fill (per-bank segments pad identically in both paths).
        assert stats["launches_fused"] * k == stats["launches_per_bank_path"]
        if k >= 4:
            assert stats["launch_ratio"] >= 2.0, stats
        per_bank_fill = batch / (-(-batch // K.LANES) * K.LANES)
        assert stats["lane_fill"] == round(per_bank_fill, 4), stats
        out.append(
            {
                "qc": qc,
                "layers": nl,
                "batch": batch,
                "n_banks": k,
                "fused_us_per_bank": round(t_fused / k * 1e6, 2),
                "per_bank_us_per_bank": round(t_per / k * 1e6, 2),
                "max_err": f"{err:.1e}",
                "launches_fused": stats["launches_fused"],
                "launches_per_bank_path": stats["launches_per_bank_path"],
                "launch_ratio": stats["launch_ratio"],
                "lane_fill": stats["lane_fill"],
            }
        )
    return out


def multiuse_rows(batch: int = 64):
    """Multi-use suffix replay: parameter-tied ansatz (every variational
    op mirrored across the register, twins SHARING the parameter — 2x the
    variational depth at the same parameter count) through the suffix-replay
    planner vs the same bank materialized.  Each variant replays only its
    parameter's dependent span [first use .. last use] from a checkpoint at
    the first use; the materialized bank re-simulates the whole circuit per
    group.  Ratios are analytic and trend-gated; wall time is interpret-mode
    color only."""
    out = []
    for qc, nl in ((5, 1), (7, 3)):
        spec = circuits.build_tied_quclassi_circuit(qc, nl)
        key = jax.random.PRNGKey(2)
        theta = jax.random.uniform(
            key, (spec.n_theta,), jnp.float32, minval=0.0, maxval=np.pi
        )
        data = jax.random.uniform(
            jax.random.fold_in(key, 1),
            (batch, spec.n_data),
            jnp.float32,
            minval=0.0,
            maxval=np.pi,
        )
        bank = shift_rule.build_shift_bank(theta, data)
        mat = bank.materialize()

        implicit = jax.jit(lambda t, d: ops.vqc_fidelity_shiftbank(spec, t, d, False))
        materialized = jax.jit(lambda t, d: ops.vqc_fidelity(spec, t, d))
        t_impl = time_fn(implicit, bank.theta, bank.data)
        t_mat = time_fn(materialized, mat.theta, mat.data)
        err = float(
            jnp.abs(
                implicit(bank.theta, bank.data) - materialized(mat.theta, mat.data)
            ).max()
        )
        assert err < 1e-5, (qc, nl, err)

        plan = K.build_shift_plan(spec)
        cost = K.shift_cost_info(spec)
        assert cost["use_implicit"], (qc, nl, cost)
        out.append(
            {
                "qc": qc,
                "layers": nl,
                "batch": batch,
                "n_params": spec.n_theta,
                "n_train_ops": len(plan.train_ops),
                "replay_depth_max": cost["replay_depth_max"],
                "implicit_us_per_circuit": round(t_impl / bank.n_circuits * 1e6, 2),
                "materialized_us_per_circuit": round(t_mat / bank.n_circuits * 1e6, 2),
                "max_err": f"{err:.1e}",
                "gate_apps_implicit": cost["gate_apps_implicit"],
                "gate_apps_materialized": cost["gate_apps_materialized"],
                "gate_apps_ratio": round(
                    cost["gate_apps_materialized"] / cost["gate_apps_implicit"], 2
                ),
            }
        )
    # acceptance: >= 3x analytic gate-application reduction on the 2-reuse
    # 7q/3l tied ansatz (each variant replays a 2-op span, not the stack)
    r7 = next(r for r in out if r["qc"] == 7 and r["layers"] == 3)
    assert r7["gate_apps_ratio"] >= 3.0, r7
    return out


def spill_overlap_rows():
    """Double-buffered spill DMAs: boundary-fetch overlap of the depth-tiled
    backward launch at the production tile (TB = 512).  overlap_ratio =
    fraction of boundary fetches issued while the previous tile computes
    ((n_tiles - 1) / n_tiles — the warm-up fetch cannot overlap);
    spill_buffer_bytes = the second ping-pong VMEM buffer the footprint now
    reports.  The live half drives the launch observer and checks the
    emitted tile events ping-pong the two buffers."""
    out = []
    for qc in (13, 17):  # m = 6 (fused), m = 8 (spilled)
        spec = circuits.build_quclassi_circuit(qc, 3)
        info = K.shift_execution_info(spec, 512)
        events = []
        prev = ops.set_launch_observer(events.append)
        try:
            ops._notify_launch(spec, 512, False, None)
        finally:
            ops.set_launch_observer(prev)
        tiles = [e for e in events if e.get("mode") == "spill_tile"]
        assert len(events) == info["launches"], (qc, events)
        assert all(
            e["buffer"] == i % 2 and e["overlapped"] == (i > 0)
            for i, e in enumerate(tiles)
        ), tiles
        out.append(
            {
                "qc": qc,
                "m": K.build_shift_plan(spec).m,
                "mode": info["mode"],
                "launches": info["launches"],
                "spill_tiles": info["n_tiles"],
                "overlap_ratio": info.get("overlap_ratio", 0.0),
                "spill_buffer_bytes": info.get("spill_buffer_bytes", 0),
                "observer_tile_events": len(tiles),
            }
        )
    wide = out[-1]
    assert wide["mode"] == "spill" and wide["observer_tile_events"] > 1, wide
    assert wide["overlap_ratio"] > 0.5, wide
    return out


def spill_rows():
    """VMEM-aware checkpoint spilling: execution-mode + launch-count report
    for widening registers at the production tile (TB = 512).  Wide
    registers (m > 6) now stay on the prefix-reuse fast path via HBM
    depth-tile spilling instead of ejecting to materialize(); all values
    are analytic and trend-gated."""
    out = []
    for qc in (7, 13, 17):          # m = 3, 6, 8
        spec = circuits.build_quclassi_circuit(qc, 3)
        info = K.shift_execution_info(spec, 512)
        plan = K.build_shift_plan(spec)
        out.append(
            {
                "qc": qc,
                "m": plan.m,
                "n_params": spec.n_theta,
                "mode": info["mode"],
                "launches": info["launches"],
                "spill_tiles": info["n_tiles"],
                "vmem_bytes": info["vmem_bytes"],
                "vmem_budget": info["vmem_budget"],
                "spilled_bytes": info.get("spilled_bytes", 0),
                "spill_buffer_bytes": info.get("spill_buffer_bytes", 0),
            }
        )
    assert out[0]["mode"] == "fused", out[0]       # narrow: single sweep
    assert out[-1]["mode"] == "spill", out[-1]     # m = 8: tiled fast path
    # tiling budgets the checkpoint set; the reported footprint additionally
    # carries the second ping-pong boundary buffer (headroom below physical
    # VMEM covers it)
    assert all(
        r["vmem_bytes"] - r["spill_buffer_bytes"] <= r["vmem_budget"] for r in out
    ), out
    return out


def _print_table(table):
    keys = list(table[0])
    print(",".join(keys))
    for r in table:
        print(",".join(str(r[k]) for k in keys))


def main(quick: bool = False):
    fused_table = rows(batch=128 if quick else 512)
    _print_table(fused_table)
    print(
        "# traffic_ratio = analytic HBM round-trips saved by gate fusion "
        "(the TPU-side win; CPU interpret-mode wall time is not indicative)"
    )

    print(
        "\n## shift-structured circuit bank: implicit + prefix-reuse vs "
        "materialized"
    )
    shift_table = shift_rows(batch=16 if quick else 64)
    _print_table(shift_table)
    print(
        "# gate_apps_ratio / angle_bytes_ratio = analytic per-step savings "
        "of the shift-structured executor (acceptance: >=5x / >=10x at "
        "7q/3l)"
    )
    r7 = next(r for r in shift_table if r["qc"] == 7 and r["layers"] == 3)
    assert r7["gate_apps_ratio"] >= 5.0, r7
    assert r7["angle_bytes_ratio"] >= 10.0, r7

    print("\n## multi-bank fused launches: K same-spec banks, one kernel " "launch")
    multibank_table = multibank_rows(batch=16 if quick else 64)
    _print_table(multibank_table)
    print(
        "# launch_ratio = K per-bank launches collapsed into one fused "
        "launch (acceptance: >= 2x at K = 4); per-lane results are "
        "bit-identical"
    )

    print(
        "\n## multi-use suffix replay: parameter-tied ansatz, per-variant "
        "span replay vs materialized"
    )
    multiuse_table = multiuse_rows(batch=16 if quick else 64)
    _print_table(multiuse_table)
    print(
        "# gate_apps_ratio = analytic gate-application reduction of "
        "suffix replay on parameter-reusing circuits (acceptance: >= 3x "
        "at tied 7q/3l)"
    )

    print(
        "\n## VMEM-aware checkpoint spilling: execution mode by register "
        "width (TB = 512)"
    )
    spill_table = spill_rows()
    _print_table(spill_table)
    print(
        "# m > 6 registers run the prefix-reuse fast path in "
        "1 + spill_tiles launches instead of falling back to the "
        "materialized bank"
    )

    print(
        "\n## double-buffered spill DMAs: boundary-fetch overlap of the "
        "depth-tiled backward launch"
    )
    overlap_table = spill_overlap_rows()
    _print_table(overlap_table)
    print(
        "# overlap_ratio = boundary fetches issued during the previous "
        "tile's compute; observer_tile_events = live per-tile launch "
        "events ping-ponging the two VMEM buffers"
    )
    return {
        "fused": fused_table,
        "shift_bank": shift_table,
        "multibank": multibank_table,
        "multiuse": multiuse_table,
        "spill": spill_table,
        "spill_overlap": overlap_table,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--quick", action="store_true", help="smaller batches (CI smoke run)"
    )
    ap.add_argument(
        "--json", metavar="PATH", help="also write the result tables to PATH as JSON"
    )
    args = ap.parse_args()
    result = main(quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {args.json}")
