"""Kernel microbenchmark (beyond paper): fused Pallas VQC kernel vs the
per-gate pure-JAX simulator on a circuit batch, plus the shift-structured
circuit-bank section (implicit ``ShiftBank`` + prefix-reuse kernel vs the
materialized bank).

On CPU the Pallas kernels run in interpret mode, so WALL TIME here is not
the TPU story; the structural wins are analytic:

  * gate fusion      — per-gate execution round-trips the statevector batch
    through HBM once per gate, the fused kernel once per circuit;
  * shift structure  — the materialized bank re-simulates every gate of all
    (1 + 2P) * B rows and reads (P + D) * (1 + 2P) angle floats per sample;
    the prefix-reuse kernel runs one data-register pass, one checkpointed
    forward + one reversed-suffix backward pass over the trainable register,
    and ONE gate + one inner product per (param, shift) variant, reading
    (P + D) floats per sample.

We report measured wall time AND the analytic ratios the roofline uses.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits, shift_rule
from repro.kernels import ops, ref
from repro.kernels import vqc_statevector as K


def time_fn(fn, *args, iters: int = 3) -> float:
    out = fn(*args)                      # warm up ONCE, bind the result
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def hbm_bytes(qc: int, n_ops: int, batch: int, fused: bool) -> int:
    """Statevector traffic: (re+im) * 4 B * 2^qc per read+write round trip."""
    state = 2 * 4 * (2 ** qc) * batch
    trips = 2 if fused else 2 * n_ops          # read+write once vs per gate
    return state * trips


def rows(batch: int = 512):
    out = []
    for qc in (5, 7):
        for nl in (1, 3):
            spec = circuits.build_quclassi_circuit(qc, nl)
            key = jax.random.PRNGKey(0)
            theta = jax.random.uniform(key, (batch, spec.n_theta), jnp.float32)
            data = jax.random.uniform(key, (batch, spec.n_data), jnp.float32)

            fused = jax.jit(lambda t, d: ops.vqc_fidelity(spec, t, d))
            pergate = jax.jit(lambda t, d: ref.vqc_fidelity_ref(spec, t, d))
            t_fused = time_fn(fused, theta, data)
            t_ref = time_fn(pergate, theta, data)
            err = float(jnp.abs(fused(theta, data) - pergate(theta, data)).max())

            bf = hbm_bytes(qc, len(spec.ops), batch, fused=True)
            bp = hbm_bytes(qc, len(spec.ops), batch, fused=False)
            out.append({
                "qc": qc, "layers": nl, "batch": batch, "n_gates": len(spec.ops),
                "fused_us_per_circuit": round(t_fused / batch * 1e6, 2),
                "pergate_us_per_circuit": round(t_ref / batch * 1e6, 2),
                "max_err": f"{err:.1e}",
                "hbm_bytes_fused": bf,
                "hbm_bytes_pergate": bp,
                "traffic_ratio": round(bp / bf, 1),
            })
    return out


def shift_rows(batch: int = 64, four_term: bool = False):
    """Implicit ShiftBank through the prefix-reuse kernel vs the same bank
    materialized through the standard fused kernel."""
    out = []
    for qc in (5, 7):
        for nl in (1, 3):
            spec = circuits.build_quclassi_circuit(qc, nl)
            key = jax.random.PRNGKey(1)
            theta = jax.random.uniform(key, (spec.n_theta,), jnp.float32,
                                       minval=0.0, maxval=np.pi)
            data = jax.random.uniform(jax.random.fold_in(key, 1),
                                      (batch, spec.n_data), jnp.float32,
                                      minval=0.0, maxval=np.pi)
            bank = shift_rule.build_shift_bank(theta, data, four_term=four_term)
            mat = bank.materialize()

            implicit = jax.jit(lambda t, d: ops.vqc_fidelity_shiftbank(
                spec, t, d, four_term))
            materialized = jax.jit(lambda t, d: ops.vqc_fidelity(spec, t, d))
            t_impl = time_fn(implicit, bank.theta, bank.data)
            t_mat = time_fn(materialized, mat.theta, mat.data)
            err = float(jnp.abs(implicit(bank.theta, bank.data)
                                - materialized(mat.theta, mat.data)).max())
            # assert on the RAW error: the displayed string is rounded to one
            # significant figure and useless at the 1e-5 boundary.
            assert err < 1e-5, (qc, nl, err)

            stats = K.shift_bank_stats(spec, batch, four_term)
            out.append({
                "qc": qc, "layers": nl, "batch": batch,
                "n_params": spec.n_theta, "n_circuits": bank.n_circuits,
                "implicit_us_per_circuit": round(
                    t_impl / bank.n_circuits * 1e6, 2),
                "materialized_us_per_circuit": round(
                    t_mat / bank.n_circuits * 1e6, 2),
                "max_err": f"{err:.1e}",
                "gate_apps_implicit": stats["gate_apps_implicit"],
                "gate_apps_materialized": stats["gate_apps_materialized"],
                "gate_apps_ratio": stats["gate_apps_ratio"],
                "angle_bytes_implicit": stats["angle_bytes_implicit"],
                "angle_bytes_materialized": stats["angle_bytes_materialized"],
                "angle_bytes_ratio": stats["angle_bytes_ratio"],
            })
    return out


def _print_table(table):
    keys = list(table[0])
    print(",".join(keys))
    for r in table:
        print(",".join(str(r[k]) for k in keys))


def main(quick: bool = False):
    fused_table = rows(batch=128 if quick else 512)
    _print_table(fused_table)
    print("# traffic_ratio = analytic HBM round-trips saved by gate fusion "
          "(the TPU-side win; CPU interpret-mode wall time is not indicative)")

    print("\n## shift-structured circuit bank: implicit + prefix-reuse vs "
          "materialized")
    shift_table = shift_rows(batch=16 if quick else 64)
    _print_table(shift_table)
    print("# gate_apps_ratio / angle_bytes_ratio = analytic per-step savings "
          "of the shift-structured executor (acceptance: >=5x / >=10x at "
          "7q/3l)")
    r7 = next(r for r in shift_table if r["qc"] == 7 and r["layers"] == 3)
    assert r7["gate_apps_ratio"] >= 5.0, r7
    assert r7["angle_bytes_ratio"] >= 10.0, r7
    return {"fused": fused_table, "shift_bank": shift_table}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller batches (CI smoke run)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the result tables to PATH as JSON")
    args = ap.parse_args()
    result = main(quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {args.json}")
