"""Kernel microbenchmark (beyond paper): fused Pallas VQC kernel vs the
per-gate pure-JAX simulator on a circuit batch.

On CPU the Pallas kernel runs in interpret mode, so WALL TIME here is not
the TPU story; the structural win is HBM traffic: per-gate execution
round-trips the statevector batch through memory once per gate, the fused
kernel once per circuit.  We report measured wall time AND the analytic
bytes-moved ratio that the roofline uses.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits
from repro.kernels import ops, ref


def time_fn(fn, *args, iters: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def hbm_bytes(qc: int, n_ops: int, batch: int, fused: bool) -> int:
    """Statevector traffic: (re+im) * 4 B * 2^qc per read+write round trip."""
    state = 2 * 4 * (2 ** qc) * batch
    trips = 2 if fused else 2 * n_ops          # read+write once vs per gate
    return state * trips


def rows(batch: int = 512):
    out = []
    for qc in (5, 7):
        for nl in (1, 3):
            spec = circuits.build_quclassi_circuit(qc, nl)
            key = jax.random.PRNGKey(0)
            theta = jax.random.uniform(key, (batch, spec.n_theta), jnp.float32)
            data = jax.random.uniform(key, (batch, spec.n_data), jnp.float32)

            fused = jax.jit(lambda t, d: ops.vqc_fidelity(spec, t, d))
            pergate = jax.jit(lambda t, d: ref.vqc_fidelity_ref(spec, t, d))
            t_fused = time_fn(fused, theta, data)
            t_ref = time_fn(pergate, theta, data)
            err = float(jnp.abs(fused(theta, data) - pergate(theta, data)).max())

            bf = hbm_bytes(qc, len(spec.ops), batch, fused=True)
            bp = hbm_bytes(qc, len(spec.ops), batch, fused=False)
            out.append({
                "qc": qc, "layers": nl, "batch": batch, "n_gates": len(spec.ops),
                "fused_us_per_circuit": round(t_fused / batch * 1e6, 2),
                "pergate_us_per_circuit": round(t_ref / batch * 1e6, 2),
                "max_err": f"{err:.1e}",
                "hbm_bytes_fused": bf,
                "hbm_bytes_pergate": bp,
                "traffic_ratio": round(bp / bf, 1),
            })
    return out


def main():
    all_rows = rows()
    keys = list(all_rows[0])
    print(",".join(keys))
    for r in all_rows:
        print(",".join(str(r[k]) for k in keys))
    print("# traffic_ratio = analytic HBM round-trips saved by gate fusion "
          "(the TPU-side win; CPU interpret-mode wall time is not indicative)")
    return all_rows


if __name__ == "__main__":
    main()
