"""Federated DQL benchmark: quorum rounds vs the sync barrier (beyond paper).

Three sections, all on the virtual clock (bit-deterministic, so the emitted
metrics are gateable against a committed baseline):

  straggler : the Fig-6-style heterogeneous tenant mix (5q/7q x 1/2 layers)
              on the 5/10/15/20-qubit fleet with a 10x slowdown fault on
              every worker that can hold the 7q banks — the scenario the
              quorum + deadline policy exists for.  Reports rounds/sec for
              the sync barrier vs quorum rounds and the straggler tax
              (``quorum_wait_share``).
  secure    : pairwise-mask secure aggregation must reproduce the plain
              FedAvg aggregate (masks cancel in the sum) — reported as a
              0/1 ``matches_plain`` plus the actual max abs difference.
  accuracy  : accuracy-vs-rounds for real QuClassi local training (exact
              autodiff SGD on per-tenant MNIST shards) through the serving
              gateway, 4 tenants at quorum 0.75.

The determinism section re-runs the straggler-quorum and accuracy runs with
the same seed and requires bit-identical reports + final parameters — the
double-run gate CI enforces via ``check_trend.py``.

Usage:  PYTHONPATH=src:. python benchmarks/federated_bench.py
            [--full] [--seed N] [--out-dir DIR] [--skip-determinism]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

#: the config CI runs (and the committed baseline was emitted with).
CI_DEFAULTS = dict(
    n_rounds=4,
    quorum=0.5,  # 2-of-4: close on the healthy-worker tenants
    n_circuits=16,
    slowdown_factor=10.0,
    accuracy_rounds=2,
    accuracy_quorum=0.75,
    n_per_class=12,
    local_steps=1,
    lr=0.1,
    seed=7,
)

FULL_OVERRIDES = dict(n_rounds=8, accuracy_rounds=5, n_per_class=40)


def _fleet():
    from repro.comanager.worker import WorkerConfig

    return [
        WorkerConfig("w1", 5),
        WorkerConfig("w2", 10),
        WorkerConfig("w3", 15),
        WorkerConfig("w4", 20),
    ]


def _fig6_tenants(n_circuits):
    from repro.federated import TenantSpec

    return [
        TenantSpec("t5a", qc=5, n_layers=1, n_circuits=n_circuits),
        TenantSpec("t5b", qc=5, n_layers=2, n_circuits=n_circuits),
        TenantSpec("t7a", qc=7, n_layers=1, n_circuits=n_circuits),
        TenantSpec("t7b", qc=7, n_layers=2, n_circuits=n_circuits),
    ]


def _toy_update_fn(seed):
    """Deterministic synthetic delta trees: seeded on (tenant, round)."""

    def update_fn(tenant, round_idx, params):
        ent = [seed, round_idx] + [ord(c) for c in tenant]
        g = np.random.default_rng(np.random.SeedSequence(ent))
        return {k: 0.01 * g.standard_normal(np.shape(v)) for k, v in params.items()}

    return update_fn


# ---------------------------------------------------------------- sections
def run_straggler(cfg):
    """Barrier vs quorum rounds under the canonical slowdown fault: every
    worker wide enough for the 7q banks runs 10x slow, so the 7q tenants
    straggle and the sync barrier pays for them every round."""
    from repro.comanager.faults import FaultSpec
    from repro.federated import FederatedConfig, run_federated

    params0 = {"theta": np.random.default_rng(cfg["seed"]).standard_normal((2, 10))}
    faults = {
        w: FaultSpec(kind="slowdown", at=0.0, factor=cfg["slowdown_factor"])
        for w in ("w2", "w3", "w4")
    }
    reports = {}
    for mode, barrier in (("barrier", True), ("quorum", False)):
        fed = FederatedConfig(
            n_rounds=cfg["n_rounds"],
            quorum=cfg["quorum"],
            barrier=barrier,
            seed=cfg["seed"],
        )
        reports[mode] = run_federated(
            fed,
            _fig6_tenants(cfg["n_circuits"]),
            _toy_update_fn(cfg["seed"]),
            params0,
            _fleet(),
            gateway=True,
            worker_failures=dict(faults),
        )
    q, b = reports["quorum"], reports["barrier"]
    return reports, {
        "rounds_completed": len(q.rounds),
        "barrier_rps": round(b.rounds_per_second, 6),
        "quorum_rps": round(q.rounds_per_second, 6),
        "quorum_over_barrier": round(
            q.rounds_per_second / max(b.rounds_per_second, 1e-9), 6
        ),
        "quorum_wait_share": round(q.quorum_wait_share, 6),
        "barrier_wait_share": round(b.quorum_wait_share, 6),
        "participation": {t: dict(c) for t, c in sorted(q.participation.items())},
    }


def run_secure(cfg):
    """Masked aggregation == plain aggregation: one in-process round each
    way over the same updates; the pairwise masks must cancel in the sum."""
    from repro.federated import FederatedConfig, FederatedCoordinator

    rng = np.random.default_rng(cfg["seed"])
    params0 = {"theta": rng.standard_normal((3, 7)), "phi": rng.standard_normal(5)}
    tenants = ["a", "b", "c", "d"]
    updates = {
        t: {k: 0.1 * rng.standard_normal(np.shape(v)) for k, v in params0.items()}
        for t in tenants
    }
    finals = {}
    for secure in (False, True):
        fed = FederatedConfig(n_rounds=1, secure_aggregation=secure, seed=cfg["seed"])
        co = FederatedCoordinator(fed, params0)
        co.begin_round(0, 0.0, tenants)
        for t in tenants:
            co.offer(t, updates[t], 0.5)
        co.close_round(1.0)
        finals[secure] = co.params
    diff = max(
        float(np.abs(finals[True][k] - finals[False][k]).max()) for k in params0
    )
    return {"matches_plain": int(diff <= 1e-6), "max_abs_diff": diff}


def run_accuracy(cfg):
    """Accuracy-vs-rounds: real QuClassi local SGD on per-tenant MNIST
    shards, aggregated through the gateway-side round loop at quorum 0.75."""
    from repro.federated import (
        FederatedConfig,
        TenantSpec,
        make_quclassi_eval_fn,
        make_quclassi_update_fn,
        run_federated,
        shard_dataset,
    )

    import jax

    from repro.core.quclassi import QuClassiConfig, init_params
    from repro.data import mnist

    qcfg = QuClassiConfig(qc=5, n_layers=1)
    x, y = mnist.make_pair_dataset(
        3, 6, n_per_class=cfg["n_per_class"], seed=cfg["seed"]
    )
    (xtr, ytr), (xte, yte) = mnist.train_test_split(x, y)
    names = ["alice", "bob", "carol", "dave"]
    shards = shard_dataset(xtr, ytr, names, seed=cfg["seed"])
    tenants = [TenantSpec(n, qc=5, n_layers=1, n_circuits=16) for n in names]
    fed = FederatedConfig(
        n_rounds=cfg["accuracy_rounds"],
        quorum=cfg["accuracy_quorum"],
        seed=cfg["seed"],
    )
    report = run_federated(
        fed,
        tenants,
        make_quclassi_update_fn(
            qcfg, shards, lr=cfg["lr"], local_steps=cfg["local_steps"]
        ),
        init_params(qcfg, jax.random.PRNGKey(cfg["seed"])),
        _fleet(),
        eval_fn=make_quclassi_eval_fn(qcfg, (xte, yte)),
        gateway=True,
    )
    return report, {
        "rounds_completed": len(report.rounds),
        "accuracy_by_round": [round(a, 6) for a in report.accuracy_by_round],
        "final_accuracy": round(report.accuracy_by_round[-1], 6),
        "rounds_per_second": round(report.rounds_per_second, 6),
    }


def _fingerprint(report):
    """Everything the double-run must reproduce bit-identically: the full
    report summary plus the final parameter bytes."""
    return (
        json.dumps(report.summary(), sort_keys=True, default=float),
        tuple((k, report.params[k].tobytes()) for k in sorted(report.params)),
    )


# -------------------------------------------------------------------- main
def run(quick=True, seed=None, skip_determinism=False):
    """Run every section and return the BENCH_federated.json payload."""
    cfg = dict(CI_DEFAULTS)
    if not quick:
        cfg.update(FULL_OVERRIDES)
    if seed is not None:
        cfg["seed"] = seed
    t0 = time.time()

    reports, straggler = run_straggler(cfg)
    print(
        f"straggler: barrier {straggler['barrier_rps']:g} rounds/s vs "
        f"quorum {straggler['quorum_rps']:g} rounds/s "
        f"({straggler['quorum_over_barrier']:g}x), quorum wait share "
        f"{straggler['quorum_wait_share']:.1%}"
    )
    secure = run_secure(cfg)
    print(
        f"secure agg: masked vs plain max |diff| = "
        f"{secure['max_abs_diff']:.2e} "
        f"({'ok' if secure['matches_plain'] else 'MISMATCH'})"
    )
    acc_report, acc = run_accuracy(cfg)
    print(
        f"accuracy: {acc['rounds_completed']} rounds -> "
        f"{acc['accuracy_by_round']} (final {acc['final_accuracy']:g})"
    )

    repeat_identical = 0
    if not skip_determinism:
        reports2, _ = run_straggler(cfg)
        acc_report2, _ = run_accuracy(cfg)
        repeat_identical = int(
            _fingerprint(reports["quorum"]) == _fingerprint(reports2["quorum"])
            and _fingerprint(acc_report) == _fingerprint(acc_report2)
        )
        print(
            f"determinism: same-seed double run "
            f"{'identical' if repeat_identical else 'DIVERGED'}"
        )
        if not repeat_identical:
            print("ERROR: same-seed federated run not reproducible", file=sys.stderr)

    return {
        "config": dict(cfg),
        "straggler": straggler,
        "secure": secure,
        "accuracy": acc,
        "determinism": {"repeat_identical": repeat_identical},
        "harness": {"wall_s": round(time.time() - t0, 1)},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--full",
        action="store_true",
        help="more rounds + larger shards (CI runs the quick defaults)",
    )
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--out-dir", default=".", help="directory for BENCH_federated.json")
    ap.add_argument(
        "--skip-determinism",
        action="store_true",
        help="skip the same-seed double run (emits repeat_identical=0)",
    )
    args = ap.parse_args(argv)
    payload = run(
        quick=not args.full,
        seed=args.seed,
        skip_determinism=args.skip_determinism,
    )
    os.makedirs(args.out_dir, exist_ok=True)
    path = os.path.join(args.out_dir, "BENCH_federated.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[artifact] wrote {path}")
    ok = payload["determinism"]["repeat_identical"] or args.skip_determinism
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
