"""Benchmark driver: one section per paper table/figure.

  Fig 3/4 : runtime + circuits/sec vs workers, IBM-Q (uncontrolled env)
  Fig 5   : one client, controlled env (GCP), qubit-capped workers
  Fig 6   : 4 concurrent clients, heterogeneous workers, multi- vs
            single-tenant
  §IV-B   : accuracy, distributed vs non-distributed  (--full only: slow)
  extra   : fused-kernel microbenchmark (beyond paper)

Usage:  PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import time


def section(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the slow accuracy training runs")
    args = ap.parse_args()
    t0 = time.time()

    from benchmarks import (kernel_bench, multitenant, runtime_controlled,
                            runtime_uncontrolled)

    section("Fig 3 + Fig 4: IBM-Q backends (uncontrolled), runtime & c/s")
    runtime_uncontrolled.main()

    section("Fig 5: controlled environment (GCP), one client")
    runtime_controlled.main()

    section("Fig 6: multi-tenant system, 4 concurrent clients")
    multitenant.main()

    section("Kernel microbenchmark: fused Pallas VQC vs per-gate (beyond paper)")
    kernel_bench.main()

    section("Noise-aware scheduling (beyond paper — the paper's §V limitation)")
    from benchmarks import noise_aware
    noise_aware.main()

    section("Serving gateway: cross-tenant circuit-bank coalescing "
            "(beyond paper)")
    from benchmarks import gateway_throughput
    gateway_throughput.main(run_kernel=args.full)

    if args.full:
        from benchmarks import accuracy
        section("§IV-B accuracy: distributed vs non-distributed")
        accuracy.main()
    else:
        section("§IV-B accuracy (skipped — pass --full; one-step gradient "
                "equivalence check only)")
        from benchmarks import accuracy
        gap = accuracy.gradient_equivalence(1, 5)
        print(f"task 1/5: max |distributed - local| theta-grad = {gap:.2e}")

    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
