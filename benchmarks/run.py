"""Benchmark driver: one section per paper table/figure.

  Fig 3/4 : runtime + circuits/sec vs workers, IBM-Q (uncontrolled env)
  Fig 5   : one client, controlled env (GCP), qubit-capped workers
  Fig 6   : 4 concurrent clients, heterogeneous workers, multi- vs
            single-tenant
  §IV-B   : accuracy, distributed vs non-distributed  (--full only: slow)
  extra   : fused-kernel + shift-bank microbenchmarks (beyond paper)

Every run emits machine-readable artifacts — ``BENCH_kernel.json`` (fused
kernel wall time + analytic traffic ratios, shift-bank gate-application and
angle-byte ratios), ``BENCH_gateway.json`` (coalescing throughput +
latency) and ``BENCH_federated.json`` (quorum vs barrier round throughput,
secure-aggregation parity, accuracy-vs-rounds) — so the perf trajectory is
tracked across PRs; CI uploads them.

Usage:  PYTHONPATH=src python -m benchmarks.run [--full | --quick]
                                                [--out-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def section(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")


def _write_artifact(out_dir: str, name: str, payload) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[artifact] wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--full", action="store_true", help="include the slow accuracy training runs"
    )
    ap.add_argument(
        "--quick",
        action="store_true",
        help="CI smoke mode: kernel + gateway sections only, "
        "small batches, still emits BENCH_*.json",
    )
    ap.add_argument(
        "--out-dir",
        default=".",
        help="directory for BENCH_kernel.json / BENCH_gateway.json",
    )
    args = ap.parse_args()
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    t0 = time.time()

    from benchmarks import gateway_throughput, kernel_bench

    if not args.quick:
        from benchmarks import multitenant, runtime_controlled, runtime_uncontrolled

        section("Fig 3 + Fig 4: IBM-Q backends (uncontrolled), runtime & c/s")
        runtime_uncontrolled.main()

        section("Fig 5: controlled environment (GCP), one client")
        runtime_controlled.main()

        section("Fig 6: multi-tenant system, 4 concurrent clients")
        multitenant.main()

    section(
        "Kernel microbenchmark: fused Pallas VQC + shift-structured "
        "banks (beyond paper)"
    )
    kernel_result = kernel_bench.main(quick=args.quick)
    _write_artifact(
        args.out_dir,
        "BENCH_kernel.json",
        {
            "wall_time_note": "CPU interpret-mode wall time; analytic ratios are "
            "the TPU-side signal",
            **kernel_result,
        },
    )

    if not args.quick:
        section("Noise-aware scheduling (beyond paper — the paper's §V limitation)")
        from benchmarks import noise_aware

        noise_aware.main()

    section("Serving gateway: cross-tenant circuit-bank coalescing (beyond paper)")
    gateway_result = gateway_throughput.main(
        run_kernel=args.full,
        scale=0.05 if args.quick else 0.25,
        trace_path=os.path.join(args.out_dir, "trace_gateway.json"),
    )
    _write_artifact(args.out_dir, "BENCH_gateway.json", gateway_result)

    section("Federated DQL: quorum rounds vs sync barrier (beyond paper)")
    from benchmarks import federated_bench

    federated_result = federated_bench.run(quick=not args.full)
    _write_artifact(args.out_dir, "BENCH_federated.json", federated_result)

    if args.full:
        from benchmarks import accuracy

        section("§IV-B accuracy: distributed vs non-distributed")
        accuracy.main()
    elif not args.quick:
        section(
            "§IV-B accuracy (skipped — pass --full; one-step gradient "
            "equivalence check only)"
        )
        from benchmarks import accuracy

        gap = accuracy.gradient_equivalence(1, 5)
        print(f"task 1/5: max |distributed - local| theta-grad = {gap:.2e}")

    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
