"""Benchmark-regression gate: freshly emitted BENCH_*.json vs baselines.

``benchmarks/run.py`` emits ``BENCH_kernel.json`` / ``BENCH_gateway.json``
every run; this script compares them against the committed baselines in
``benchmarks/baselines/`` and exits non-zero when a gated metric regressed
past its tolerance band — the cross-PR trend check CI runs after the
benchmark smoke step.

Only MACHINE-INDEPENDENT metrics are gated: analytic ratios (gate
applications, angle bytes, HBM traffic) and virtual-clock results
(circuits/sec, lane fill, SLO attainment) are bit-deterministic across
hosts, so a committed baseline is meaningful.  Wall-clock numbers
(``*_us_per_circuit``, real-kernel c/s) vary wildly between the committing
machine and a CI runner and are reported informationally only.

Every gate is evaluated in one pass — ALL out-of-band metrics are reported
together (never fail-on-first), and when ``$GITHUB_STEP_SUMMARY`` is set a
markdown comparison table of every gated metric lands on the workflow run
page.

Usage:
    python benchmarks/check_trend.py [--emitted DIR] [--baselines DIR]
                                     [--artifacts A.json,B.json]
                                     [--tolerance-scale S]
                                     [--update-baselines]

``--artifacts`` restricts the pass to a subset (the tier-1 job gates the
kernel + gateway + federated artifacts; the scale job gates
``BENCH_scale.json``, which tier-1 never emits).  ``--update-baselines`` copies the emitted
artifacts over the committed baselines (run after an intentional perf
change, then commit the diff).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import sys

ARTIFACTS = (
    "BENCH_kernel.json",
    "BENCH_gateway.json",
    "BENCH_scale.json",
    "BENCH_federated.json",
)

#: (artifact, path regex, direction, relative tolerance).  ``higher`` means
#: the metric regressed if current < baseline * (1 - tol); ``lower`` means
#: regressed if current > baseline * (1 + tol).  Analytic ratios are
#: deterministic, so their band is tight; virtual-clock throughput gets the
#: 25% band (scheduling-policy tweaks legitimately move it a little).
GATES = [
    ("BENCH_kernel.json", r"fused\.\d+\.traffic_ratio$", "higher", 0.01),
    ("BENCH_kernel.json", r"shift_bank\.\d+\.gate_apps_ratio$", "higher", 0.01),
    ("BENCH_kernel.json", r"shift_bank\.\d+\.angle_bytes_ratio$", "higher", 0.01),
    # fused multi-bank launches: K-bank launch collapse.  lane_fill is NOT
    # gated: it depends on the bench batch size (--quick vs full emit
    # different values), and gating it would trap a baseline refresh from a
    # full run; kernel_bench asserts lane-fill parity analytically instead.
    ("BENCH_kernel.json", r"multibank\.\d+\.launch_ratio$", "higher", 0.01),
    # multi-use suffix replay: per-variant span replay on parameter-tied
    # circuits; the ratio dropping means variants started re-simulating
    # more than their dependent span
    ("BENCH_kernel.json", r"multiuse\.\d+\.gate_apps_ratio$", "higher", 0.01),
    # VMEM-aware checkpoint spilling: launch counts are analytic; more
    # launches for a given register width = a perf regression
    ("BENCH_kernel.json", r"spill\.\d+\.launches$", "lower", 0.01),
    # double-buffered spill DMAs: the backward launch must keep overlapping
    # boundary fetches with compute, without growing the launch count
    ("BENCH_kernel.json", r"spill_overlap\.\d+\.overlap_ratio$", "higher", 0.01),
    ("BENCH_kernel.json", r"spill_overlap\.\d+\.launches$", "lower", 0.01),
    ("BENCH_gateway.json", r"^system_cps_gateway$", "higher", 0.25),
    ("BENCH_gateway.json", r"^system_gain$", "higher", 0.25),
    ("BENCH_gateway.json", r"fig6\.\d+\.cps_gateway$", "higher", 0.25),
    ("BENCH_gateway.json", r"sync_vs_async\.async_over_sync$", "higher", 0.25),
    ("BENCH_gateway.json", r"poisson\.lane_fill$", "higher", 0.25),
    ("BENCH_gateway.json", r"poisson\.slo_attainment$", "higher", 0.10),
    ("BENCH_gateway.json", r"poisson\.tenants\.\d+\.p99_latency_s$", "lower", 0.25),
    # observability layer (virtual clock, so deterministic): tracing must
    # keep covering the run — event count shrinking past the band means a
    # lifecycle hook got dropped — and circuits must not start spending a
    # larger share of their end-to-end latency waiting in the coalescer.
    ("BENCH_gateway.json", r"poisson\.observability\.events$", "higher", 0.25),
    (
        "BENCH_gateway.json",
        r"poisson\.observability\.stages\.coalesce_wait_share$",
        "lower",
        0.25,
    ),
    # failure-tolerant dispatch (virtual clock, deterministic): the
    # canonical crash scenario must keep migrating batches off the dead
    # worker, and the system must keep absorbing the crash — every circuit
    # completed, SLO attainment held
    ("BENCH_gateway.json", r"chaos\.migrated_batches$", "higher", 0.25),
    ("BENCH_gateway.json", r"chaos\.completed_fraction$", "higher", 0.01),
    ("BENCH_gateway.json", r"chaos\.slo_attainment$", "higher", 0.10),
    # scale harness (virtual clock, fully seeded -> deterministic): the
    # 1k-tenant storm's throughput knee must not move down, latency at 80%
    # of the knee must not inflate, and knee-calibrated admission control
    # must keep shedding load past the knee while holding the admitted
    # circuits' SLO attainment.
    ("BENCH_scale.json", r"^knee\.offered_cps$", "higher", 0.25),
    ("BENCH_scale.json", r"^knee\.achieved_cps$", "higher", 0.25),
    ("BENCH_scale.json", r"^knee\.p99_latency_s$", "lower", 0.25),
    ("BENCH_scale.json", r"^p99_at_80pct_knee_s$", "lower", 0.25),
    ("BENCH_scale.json", r"^attainment_at_knee$", "higher", 0.10),
    ("BENCH_scale.json", r"^admission\.reject_fraction$", "higher", 0.25),
    ("BENCH_scale.json", r"^admission\.attainment_admitted$", "higher", 0.10),
    # same-seed double run must be bit-identical (1 = identical, 0 = drift)
    ("BENCH_scale.json", r"^determinism\.repeat_identical$", "higher", 0.0),
    # federated rounds (virtual clock, fully seeded -> deterministic): every
    # configured round must close, quorum rounds must keep beating the sync
    # barrier under the canonical straggler fault, the straggler tax must
    # not inflate, masked aggregation must keep reproducing plain FedAvg,
    # and the same-seed double run (round records + final params) must stay
    # bit-identical.
    ("BENCH_federated.json", r"^straggler\.rounds_completed$", "higher", 0.0),
    ("BENCH_federated.json", r"^straggler\.quorum_over_barrier$", "higher", 0.25),
    ("BENCH_federated.json", r"^straggler\.quorum_wait_share$", "lower", 0.25),
    ("BENCH_federated.json", r"^accuracy\.rounds_completed$", "higher", 0.0),
    ("BENCH_federated.json", r"^secure\.matches_plain$", "higher", 0.0),
    ("BENCH_federated.json", r"^determinism\.repeat_identical$", "higher", 0.0),
]

#: substrings marking wall-clock metrics: never gated, listed informationally.
WALL_CLOCK_MARKERS = ("us_per_circuit", "_cps", "speedup")


def flatten(obj, prefix=""):
    """JSON tree -> {dot.path: numeric leaf} (bools and strings skipped)."""
    out = {}
    if isinstance(obj, dict):
        items = obj.items()
    elif isinstance(obj, list):
        items = ((str(i), v) for i, v in enumerate(obj))
    else:
        if isinstance(obj, (int, float)) and not isinstance(obj, bool):
            out[prefix] = float(obj)
        return out
    for k, v in items:
        path = f"{prefix}.{k}" if prefix else str(k)
        out.update(flatten(v, path))
    return out


def load(path):
    with open(path) as f:
        return flatten(json.load(f))


def step_summary(rows, failures, path):
    """Append the comparison as a markdown table to ``path`` (the file
    ``$GITHUB_STEP_SUMMARY`` points at on a CI runner)."""
    lines = ["## Benchmark trend gate", ""]
    if rows:
        lines += [
            "| artifact | metric | baseline | current | change | status |",
            "|---|---|---:|---:|---:|---|",
        ]
        for artifact, metric, base, cur, delta, direction, tol, bad in rows:
            status = "**REGRESSED**" if bad else "ok"
            lines.append(
                f"| {artifact} | `{metric}` | {base:g} | {cur:g} "
                f"| {delta:+.1%} | {status} |"
            )
    gate_errors = [f for f in failures if ":" not in f or "vs baseline" not in f]
    if gate_errors:
        lines += [""] + [f"- {f}" for f in gate_errors]
    n_bad = sum(1 for r in rows if r[-1])
    lines += ["", f"**{n_bad} regressed / {len(rows)} gated metrics**", ""]
    with open(path, "a") as f:
        f.write("\n".join(lines))


def check(
    emitted_dir, baseline_dir, tolerance_scale=1.0, verbose=True, artifacts=None
):
    """Returns a list of regression strings (empty = gate passes).

    Every gate across every artifact is evaluated before returning, so one
    run reports ALL out-of-band metrics; ``artifacts`` restricts the pass
    (default: all known artifacts).  With ``$GITHUB_STEP_SUMMARY`` set, the
    full comparison lands there as a markdown table.
    """
    artifacts = ARTIFACTS if artifacts is None else tuple(artifacts)
    failures = []
    rows = []
    for artifact in artifacts:
        emitted_path = os.path.join(emitted_dir, artifact)
        baseline_path = os.path.join(baseline_dir, artifact)
        if not os.path.exists(emitted_path):
            failures.append(
                f"{artifact}: not emitted in {emitted_dir} "
                f"(run benchmarks/run.py --quick first)"
            )
            continue
        if not os.path.exists(baseline_path):
            failures.append(
                f"{artifact}: no baseline in {baseline_dir} "
                f"(run with --update-baselines and commit)"
            )
            continue
        current = load(emitted_path)
        baseline = load(baseline_path)
        gates = [g for g in GATES if g[0] == artifact]
        for _, pattern, direction, tol in gates:
            tol = tol * tolerance_scale
            matched = [p for p in baseline if re.search(pattern, p)]
            if not matched:
                failures.append(
                    f"{artifact}: gate {pattern!r} matches " f"nothing in the baseline"
                )
            for path in sorted(matched):
                base = baseline[path]
                if path not in current:
                    failures.append(
                        f"{artifact}:{path}: gated metric "
                        f"missing from the emitted artifact "
                        f"(baseline {base}); if intentional, "
                        f"--update-baselines"
                    )
                    continue
                cur = current[path]
                if direction == "higher":
                    bad = cur < base * (1.0 - tol)
                else:
                    bad = cur > base * (1.0 + tol)
                delta = (cur - base) / base if base else 0.0
                rows.append((artifact, path, base, cur, delta, direction, tol, bad))
                if bad:
                    failures.append(
                        f"{artifact}:{path}: {cur:g} vs baseline {base:g} "
                        f"({delta:+.1%}, tolerance {tol:.0%}, "
                        f"want {direction})"
                    )
    if verbose:
        print(
            f"{'artifact':<19} {'metric':<42} {'baseline':>10} "
            f"{'current':>10} {'change':>8}  status"
        )
        for artifact, path, base, cur, delta, direction, tol, bad in rows:
            status = "REGRESSED" if bad else "ok"
            print(
                f"{artifact:<19} {path:<42} {base:>10g} {cur:>10g} "
                f"{delta:>+8.1%}  {status}"
            )
        wall = []
        for artifact in artifacts:
            path = os.path.join(emitted_dir, artifact)
            if os.path.exists(path):
                wall += [
                    f"{artifact}:{p}={v:g}"
                    for p, v in load(path).items()
                    if any(m in p for m in WALL_CLOCK_MARKERS)
                    and not any(re.search(g[1], p) for g in GATES)
                ]
        if wall:
            print(
                f"# {len(wall)} wall-clock metrics not gated "
                f"(machine-dependent), e.g. {wall[0]}"
            )
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        step_summary(rows, failures, summary_path)
    return failures


def update_baselines(emitted_dir, baseline_dir, artifacts=None):
    artifacts = ARTIFACTS if artifacts is None else tuple(artifacts)
    os.makedirs(baseline_dir, exist_ok=True)
    for artifact in artifacts:
        src = os.path.join(emitted_dir, artifact)
        if not os.path.exists(src):
            sys.exit(
                f"cannot update baselines: {src} missing "
                f"(run benchmarks/run.py --quick first)"
            )
        shutil.copy(src, os.path.join(baseline_dir, artifact))
        print(f"baseline updated: {os.path.join(baseline_dir, artifact)}")


def main(argv=None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--emitted",
        default=".",
        help="directory holding the freshly emitted BENCH_*.json",
    )
    ap.add_argument(
        "--baselines",
        default=os.path.join(here, "baselines"),
        help="directory holding the committed baselines",
    )
    ap.add_argument(
        "--artifacts",
        default=None,
        help="comma-separated subset of artifacts to gate "
        f"(default: all of {', '.join(ARTIFACTS)})",
    )
    ap.add_argument(
        "--tolerance-scale",
        type=float,
        default=1.0,
        help="multiply every gate's tolerance band (e.g. 2.0 to "
        "loosen all bands while bisecting)",
    )
    ap.add_argument(
        "--update-baselines",
        action="store_true",
        help="copy the emitted artifacts over the baselines",
    )
    args = ap.parse_args(argv)
    artifacts = None
    if args.artifacts:
        artifacts = tuple(a.strip() for a in args.artifacts.split(",") if a.strip())
        unknown = sorted(set(artifacts) - set(ARTIFACTS))
        if unknown:
            ap.error(f"unknown artifact(s) {unknown}; known: {list(ARTIFACTS)}")
    if args.update_baselines:
        update_baselines(args.emitted, args.baselines, artifacts)
        return 0
    failures = check(
        args.emitted, args.baselines, args.tolerance_scale, artifacts=artifacts
    )
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbenchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
